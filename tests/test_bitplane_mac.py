"""Plane-batched bit-serial engine + fused bitplane_mac kernel tests.

The contract, in increasing order of fusion:

  seed per-plane loop  ==  plane-batched engine  ==  fused Pallas kernel

bit-exact (noise-free), with the first two ALSO drawing identical PRNG noise
per plane pair (fold_in(key, p * bits_w + q) inside the batch via vmap).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitserial import (batched_group_counts, bitserial_matmul_looped,
                                  bitserial_matmul_unsigned, group_counts,
                                  plane_pair_weights)
from repro.core.imc_matmul import imc_matmul, int_matmul
from repro.core.quant import quantize, to_bitplanes, to_offset_binary
from repro.kernels.bitplane_mac.ops import bitplane_mac
from repro.kernels.bitplane_mac.ref import (bitplane_mac_batched_ref,
                                            bitplane_mac_ref)


def _mk_unsigned(bits, m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    hi = 1 << bits
    ua = jnp.asarray(rng.integers(0, hi, size=(m, k)).astype(np.int32))
    uw = jnp.asarray(rng.integers(0, hi, size=(k, n)).astype(np.int32))
    return ua, uw


# ------------------------------------------------- plane-batched jnp engine
def test_batched_group_counts_match_per_pair():
    rng = np.random.default_rng(1)
    ua, uw = _mk_unsigned(4, 3, 21, 6, seed=1)
    a_planes = to_bitplanes(ua, 4)
    w_planes = to_bitplanes(uw, 4)
    batched = np.asarray(batched_group_counts(a_planes, w_planes))
    for p in range(4):
        for q in range(4):
            ref = np.asarray(group_counts(a_planes[p], w_planes[q]))
            np.testing.assert_array_equal(batched[p * 4 + q], ref)


def test_plane_pair_weights_shift_table():
    w = np.asarray(plane_pair_weights(3, 2))
    assert w.tolist() == [1 << (p + q) for p in range(3) for q in range(2)]


@pytest.mark.parametrize("bits", [4, 6, 8])
def test_batched_engine_bitexact_vs_seed_loop_sim(bits):
    ua, uw = _mk_unsigned(bits, 5, 37, 9, seed=bits)
    a = bitserial_matmul_unsigned(ua, uw, bits_a=bits, bits_w=bits, mode="sim")
    b = bitserial_matmul_looped(ua, uw, bits_a=bits, bits_w=bits, mode="sim")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("bits", [4, 8])
def test_batched_engine_exact_mode_telescopes_to_int_matmul(bits):
    ua, uw = _mk_unsigned(bits, 4, 29, 7, seed=10 + bits)
    out = bitserial_matmul_unsigned(ua, uw, bits_a=bits, bits_w=bits,
                                    mode="exact")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ua) @ np.asarray(uw))


def test_mixed_precision_planes():
    ua, uw = _mk_unsigned(6, 3, 17, 5, seed=3)
    uw = uw % (1 << 4)
    out = bitserial_matmul_unsigned(ua, uw, bits_a=6, bits_w=4, mode="sim")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ua) @ np.asarray(uw))


@pytest.mark.parametrize("bits", [4, 8])
def test_batched_engine_mismatch_noise_matches_loop_keys(bits):
    """Per-plane-pair fold_in inside the batch == the loop's key schedule."""
    ua, uw = _mk_unsigned(bits, 4, 33, 6, seed=20 + bits)
    key = jax.random.key(7)
    a = bitserial_matmul_unsigned(ua, uw, bits_a=bits, bits_w=bits,
                                  mode="sim", key=key, mismatch=True)
    b = bitserial_matmul_looped(ua, uw, bits_a=bits, bits_w=bits,
                                mode="sim", key=key, mismatch=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # different keys must draw different noise somewhere on a big-k problem
    ua2, uw2 = _mk_unsigned(bits, 16, 256, 16, seed=30)
    y1 = bitserial_matmul_unsigned(ua2, uw2, bits_a=bits, bits_w=bits,
                                   mode="sim", key=jax.random.key(0),
                                   mismatch=True)
    y2 = bitserial_matmul_unsigned(ua2, uw2, bits_a=bits, bits_w=bits,
                                   mode="sim", key=jax.random.key(1),
                                   mismatch=True)
    assert not np.array_equal(np.asarray(y1), np.asarray(y2))


def test_batched_engine_comparator_offset_matches_loop_keys():
    ua, uw = _mk_unsigned(4, 4, 24, 5, seed=40)
    key = jax.random.key(11)
    a = bitserial_matmul_unsigned(ua, uw, bits_a=4, bits_w=4, mode="sim",
                                  key=key, comparator_offset_sigma=0.02)
    b = bitserial_matmul_looped(ua, uw, bits_a=4, bits_w=4, mode="sim",
                                key=key, comparator_offset_sigma=0.02)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batched_engine_noise_requires_key():
    ua, uw = _mk_unsigned(4, 2, 16, 3, seed=50)
    with pytest.raises(ValueError):
        bitserial_matmul_unsigned(ua, uw, bits_a=4, bits_w=4, mode="sim",
                                  mismatch=True)


# ------------------------------------------------------ fused Pallas kernel
@pytest.mark.parametrize("bits,m,k,n", [(4, 8, 16, 8), (8, 16, 24, 8),
                                        (6, 5, 40, 12)])
def test_bitplane_kernel_bitexact_vs_both_refs(bits, m, k, n):
    ua, uw = _mk_unsigned(bits, m, k, n, seed=hash((bits, m)) % 2**32)
    out = bitplane_mac(ua, uw, bits_a=bits, bits_w=bits, interpret=True)
    ref_loop = bitplane_mac_ref(ua, uw, bits_a=bits, bits_w=bits)
    ref_batched = bitplane_mac_batched_ref(ua, uw, bits_a=bits, bits_w=bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_loop))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_batched))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ua) @ np.asarray(uw))


@pytest.mark.slow
def test_bitplane_kernel_multiblock_ragged():
    # spans multiple (bm, bn, bk) blocks with ragged remainders everywhere
    ua, uw = _mk_unsigned(4, 140, 300, 135, seed=60)
    out = bitplane_mac(ua, uw, bits_a=4, bits_w=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ua) @ np.asarray(uw))


def test_bitplane_kernel_batch_dims():
    rng = np.random.default_rng(70)
    ua = jnp.asarray(rng.integers(0, 16, size=(2, 3, 40)).astype(np.int32))
    uw = jnp.asarray(rng.integers(0, 16, size=(40, 6)).astype(np.int32))
    out = bitplane_mac(ua, uw, bits_a=4, bits_w=4, interpret=True)
    assert out.shape == (2, 3, 6)
    ref = np.asarray(ua).reshape(6, 40) @ np.asarray(uw)
    np.testing.assert_array_equal(np.asarray(out).reshape(6, 6), ref)


def test_bitplane_kernel_custom_thresholds_detune():
    # Shifting every comparator reference up one level (paper §IV-C corner
    # detuning) must corrupt the decode — proves thresholds are live data.
    from repro.core.decoder import thresholds as core_thresholds

    ua = jnp.full((8, 16), 3, jnp.int32)
    uw = jnp.full((16, 8), 3, jnp.int32)
    good = core_thresholds(8, mode="physics")
    out_good = bitplane_mac(ua, uw, good, bits_a=2, bits_w=2, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_good),
                                  np.asarray(ua) @ np.asarray(uw))
    detuned = jnp.concatenate([jnp.array([1.9]), good[:-1]])
    out_bad = bitplane_mac(ua, uw, detuned, bits_a=2, bits_w=2,
                           interpret=True)
    assert not np.array_equal(np.asarray(out_bad), np.asarray(out_good))


# ------------------------------------------------------- fabric wiring
def test_fabric_matmul_sim_fused_kernel_matches_jnp_sim():
    from repro.core.fabric import FabricSpec

    rng = np.random.default_rng(80)
    x = jnp.asarray(rng.normal(size=(4, 24)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(24, 8)).astype(np.float32))
    ys = imc_matmul(x, w, FabricSpec(bits_a=4, bits_w=4, mode="sim",
                                     backend="jnp"))
    yk = imc_matmul(x, w, FabricSpec(bits_a=4, bits_w=4, mode="sim",
                                     backend="pallas"))
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(yk))
    ye = imc_matmul(x, w, FabricSpec(bits_a=4, bits_w=4, mode="exact"))
    np.testing.assert_allclose(np.asarray(ye), np.asarray(yk), rtol=1e-6)


def test_exact_mode_telescopes_to_int_matmul_quantized():
    # The full quantize -> offset-binary -> pyramid pipeline in exact mode
    # equals the plain int8 matmul on the quantized operands.
    rng = np.random.default_rng(82)
    x = jnp.asarray(rng.normal(size=(6, 40)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(40, 10)).astype(np.float32))
    bits = 8
    qx, qw = quantize(x, bits), quantize(w, bits, axis=0)
    ua, uw = to_offset_binary(qx.q, bits), to_offset_binary(qw.q, bits)
    from repro.core.quant import signed_product_correction

    uu = bitserial_matmul_unsigned(ua, uw, bits_a=bits, bits_w=bits,
                                   mode="exact")
    acc = uu - signed_product_correction(ua, uw, bits)
    np.testing.assert_array_equal(np.asarray(acc),
                                  np.asarray(int_matmul(qx.q, qw.q)))
