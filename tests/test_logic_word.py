"""Word-level MAC-derived logic: packed bitwise ops + ripple-carry addition
(paper §III, Table II — 8 columns evaluated in parallel per activation)."""
import jax
import numpy as np
import pytest

from repro.core.fabric import Fabric, FabricSpec, NoiseSpec
from repro.core.logic import (WORD_OPS, add_nbit, logic_word, pack_word,
                              unpack_word)

RNG = np.random.default_rng(0)
A8 = RNG.integers(0, 256, size=(5, 7)).astype(np.uint8)
B8 = RNG.integers(0, 256, size=(5, 7)).astype(np.uint8)

REF = {
    "AND": lambda a, b: a & b,
    "NAND": lambda a, b: ~(a & b),
    "OR": lambda a, b: a | b,
    "NOR": lambda a, b: ~(a | b),
    "XOR": lambda a, b: a ^ b,
    "XNOR": lambda a, b: ~(a ^ b),
}


def test_pack_unpack_roundtrip():
    planes = unpack_word(A8, 8)
    assert planes.shape == A8.shape + (8,)
    assert np.array_equal(np.asarray(pack_word(planes)), A8)


@pytest.mark.parametrize("op", WORD_OPS)
def test_logic_word_matches_bitwise(op):
    got = np.asarray(logic_word(A8, B8, op))
    assert np.array_equal(got, (REF[op](A8, B8)) & 0xFF), op


def test_logic_word_narrow_width():
    a = A8 & 0xF
    b = B8 & 0xF
    got = np.asarray(logic_word(a, b, "NOR", bits=4))
    assert np.array_equal(got, ~(a | b) & 0xF)


def test_logic_word_rejects_non_word_ops():
    with pytest.raises(ValueError):
        logic_word(A8, B8, "SUM")  # SUM/CARRY are adder reads, not word ops


def test_wide_words_do_not_truncate():
    a = np.uint16(0x1F0)
    b = np.uint16(0x10F)
    assert int(logic_word(a, b, "OR", bits=16)) == 0x1FF
    s, c = add_nbit(np.uint16(0x0180), np.uint16(0x0080), bits=16)
    assert int(s) == 0x0200 and int(c) == 0


@pytest.mark.parametrize("bits", [4, 8, 12])
def test_add_nbit_ripple_carry(bits):
    mask = (1 << bits) - 1
    rng = np.random.default_rng(bits)
    a = rng.integers(0, mask + 1, size=(5, 7)).astype(np.uint16)
    b = rng.integers(0, mask + 1, size=(5, 7)).astype(np.uint16)
    s, c = add_nbit(a, b, bits=bits)
    ref = a.astype(int) + b.astype(int)
    assert np.array_equal(np.asarray(s).astype(int), ref & mask)
    assert np.array_equal(np.asarray(c).astype(int), ref >> bits)


def test_fabric_sim_decode_matches_digital():
    """Noise-free analog decode (voltage + comparators) is bit-exact."""
    fab = Fabric(FabricSpec(mode="sim", backend="jnp"))
    assert np.array_equal(np.asarray(fab.logic_word(A8, B8, "XNOR")),
                          ~(A8 ^ B8) & 0xFF)
    s, c = fab.add_nbit(A8, B8)
    ref = A8.astype(int) + B8.astype(int)
    assert np.array_equal(np.asarray(s), (ref & 0xFF).astype(np.uint8))
    assert np.array_equal(np.asarray(c), (ref >> 8).astype(np.uint8))


def test_fabric_noisy_word_logic_keyed():
    fab = Fabric(FabricSpec(mode="sim", backend="jnp",
                            noise=NoiseSpec(mismatch_sigma=0.05)))
    k = jax.random.key(3)
    x1 = np.asarray(fab.logic_word(A8, B8, "XOR", key=k))
    x2 = np.asarray(fab.logic_word(A8, B8, "XOR", key=k))
    assert np.array_equal(x1, x2), "same key must reproduce"
    s1, _ = fab.add_nbit(A8, B8, key=k)
    s2, _ = fab.add_nbit(A8, B8, key=k)
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    with pytest.raises(ValueError, match="noisy"):
        fab.logic_word(A8, B8, "XOR")
    with pytest.raises(ValueError, match="noisy"):
        fab.add_nbit(A8, B8)
