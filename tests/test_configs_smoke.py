"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step + prefill/decode on CPU; shapes and finiteness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_config
from repro.models import decode_step, forward_logits, init_params, loss_fn, prefill

B, S = 2, 32

# Heavyweight sweeps (multi-second jit per arch on CPU): slow-marked so the
# PR tier (-m "not slow") keeps one transformer (qwen2.5-3b) + the paper
# config as smoke coverage; pushes to main run everything.
SLOW_ARCHS = frozenset({
    "recurrentgemma-9b", "gemma3-12b", "musicgen-large", "dbrx-132b",
    "mamba2-370m", "qwen3-moe-30b-a3b", "llava-next-mistral-7b",
    "deepseek-coder-33b", "qwen2-72b",
})


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS else a
            for a in archs]


def _batch(cfg, key):
    kt, kl, ke = jax.random.split(key, 3)
    batch = {"labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend != "none":
        batch["embeddings"] = jax.random.normal(
            ke, (B, S, cfg.frontend_dim), jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch",
                         _arch_params(ASSIGNED_ARCHS + ("imc-paper-110m",)))
def test_smoke_train_step(arch):
    cfg = reduce_config(get_config(arch))
    params = init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # rough sanity: CE near ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(metrics["ce"]) \
        < 2.5 * np.log(cfg.vocab_size)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), \
        f"{arch}: non-finite grads"
    logits = forward_logits(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("arch", _arch_params(ASSIGNED_ARCHS))
def test_smoke_prefill_decode(arch):
    cfg = reduce_config(get_config(arch))
    params = init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    logits0, cache = prefill(params, batch, cfg)
    assert logits0.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits0)))
    assert int(cache.pos) == S
    tok = jnp.argmax(logits0, axis=-1)[:, None].astype(jnp.int32)
    logits1, cache = decode_step(params, cache, tok, cfg)
    assert logits1.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits1)))
    assert int(cache.pos) == S + 1
    # a second decode step keeps the cache pytree structure stable
    logits2, cache2 = decode_step(params, cache, tok, cfg)
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", _arch_params(["qwen2.5-3b", "gemma3-12b",
                                               "recurrentgemma-9b",
                                               "mamba2-370m"]))
def test_decode_matches_full_forward(arch):
    """Greedy decode logits must match teacher-forced full-forward logits."""
    cfg = reduce_config(get_config(arch))
    params = init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    full = forward_logits(params, {"tokens": tokens}, cfg)

    _, cache = prefill(params, {"tokens": tokens[:, :S - 1]}, cfg,
                       max_new_tokens=4)
    logits, _ = decode_step(params, cache, tokens[:, S - 1:S], cfg)
    ref = np.asarray(full[:, S - 1], np.float32)
    got = np.asarray(logits, np.float32)
    np.testing.assert_allclose(got, ref, rtol=0.06, atol=0.08)


def test_kv_int8_cache_decode_accuracy():
    """int8 KV cache (decode-memory optimization) stays close to bf16 path."""
    import dataclasses
    cfg = reduce_config(get_config("qwen2.5-3b"))
    cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
    params = init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    full = forward_logits(params, {"tokens": tokens}, cfg)
    _, cache = prefill(params, {"tokens": tokens[:, :S - 1]}, cfg8,
                       max_new_tokens=4)
    assert cache.groups[0].k.dtype == jnp.int8
    assert cache.groups[0].k_scale is not None
    logits, cache2 = decode_step(params, cache, tokens[:, S - 1:S], cfg8)
    ref = np.asarray(full[:, S - 1], np.float32)
    got = np.asarray(logits, np.float32)
    # int8 cache: slightly looser than the bf16 decode equivalence test
    np.testing.assert_allclose(got, ref, rtol=0.12, atol=0.25)
    assert cache2.groups[0].k.dtype == jnp.int8


def test_imc_mode_changes_logits_but_not_structure():
    cfg = reduce_config(get_config("qwen2.5-3b"))
    import dataclasses
    cfg_imc = dataclasses.replace(cfg, imc_mode="exact")
    params = init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    a = forward_logits(params, batch, cfg)
    b = forward_logits(params, batch, cfg_imc)
    assert a.shape == b.shape
    # int8 path approximates the float path
    rel = (np.linalg.norm(np.asarray(a - b))
           / max(np.linalg.norm(np.asarray(a)), 1e-6))
    assert 0 < rel < 0.15
