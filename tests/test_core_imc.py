"""Quantization / bit-serial / imc_matmul correctness tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitserial import bitserial_matmul_unsigned, decode_group_counts, group_counts
from repro.core.fabric import Fabric, FabricSpec, NoiseSpec
from repro.core.imc_linear import apply_imc_linear, init_imc_linear
from repro.core.imc_matmul import imc_matmul, imc_matmul_cost, int_matmul
from repro.core.quant import (dequantize, from_bitplanes, quantize,
                              signed_product_correction, to_bitplanes,
                              to_offset_binary)


# ----------------------------------------------------------------- quantize
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("axis", [None, 0])
def test_quant_roundtrip_error_bound(bits, axis):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(32, 64)).astype(np.float32)
    qx = quantize(jnp.asarray(x), bits, axis=axis)
    err = np.abs(np.asarray(dequantize(qx)) - x)
    # max error is half a quantization step per element
    step = np.asarray(qx.scale)
    assert np.all(err <= 0.5 * step + 1e-6)


def test_bitplane_roundtrip():
    rng = np.random.default_rng(1)
    u = rng.integers(0, 256, size=(5, 17)).astype(np.int32)
    planes = to_bitplanes(jnp.asarray(u), 8)
    assert planes.shape == (8, 5, 17)
    assert set(np.unique(np.asarray(planes))) <= {0, 1}
    np.testing.assert_array_equal(from_bitplanes(planes), u)


def test_offset_binary_correction_identity():
    rng = np.random.default_rng(2)
    qa = rng.integers(-127, 128, size=(6, 24)).astype(np.int32)
    qw = rng.integers(-127, 128, size=(24, 10)).astype(np.int32)
    ua, uw = to_offset_binary(jnp.asarray(qa)), to_offset_binary(jnp.asarray(qw))
    unsigned = jnp.asarray(ua) @ jnp.asarray(uw)
    corr = signed_product_correction(ua, uw)
    np.testing.assert_array_equal(np.asarray(unsigned - corr), qa @ qw)


# ---------------------------------------------------------------- bitserial
def test_group_counts_match_blocked_popcount():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 2, size=(4, 19)).astype(np.uint8)  # K=19 -> pad to 24
    w = rng.integers(0, 2, size=(19, 7)).astype(np.uint8)
    counts = np.asarray(group_counts(jnp.asarray(a), jnp.asarray(w)))
    assert counts.shape == (4, 3, 7)
    assert counts.min() >= 0 and counts.max() <= 8
    np.testing.assert_array_equal(counts.sum(axis=1),
                                  a.astype(np.int32) @ w.astype(np.int32))


def test_decode_exact_vs_sim_noiseless_identical():
    rng = np.random.default_rng(4)
    counts = jnp.asarray(rng.integers(0, 9, size=(5, 4, 3)))
    exact = decode_group_counts(counts, mode="exact")
    sim = decode_group_counts(counts, mode="sim")
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(sim))


@pytest.mark.parametrize("bits", [2, 4])
def test_bitserial_matmul_equals_integer_matmul(bits):
    rng = np.random.default_rng(5)
    hi = 1 << bits
    ua = jnp.asarray(rng.integers(0, hi, size=(3, 21)).astype(np.int32))
    uw = jnp.asarray(rng.integers(0, hi, size=(21, 6)).astype(np.int32))
    out = bitserial_matmul_unsigned(ua, uw, bits_a=bits, bits_w=bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ua) @ np.asarray(uw))


def test_bitserial_sim_noiseless_equals_exact():
    rng = np.random.default_rng(6)
    ua = jnp.asarray(rng.integers(0, 16, size=(2, 16)).astype(np.int32))
    uw = jnp.asarray(rng.integers(0, 16, size=(16, 4)).astype(np.int32))
    a = bitserial_matmul_unsigned(ua, uw, bits_a=4, bits_w=4, mode="exact")
    b = bitserial_matmul_unsigned(ua, uw, bits_a=4, bits_w=4, mode="sim")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------- imc_matmul
def test_imc_matmul_exact_close_to_float():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    y = imc_matmul(x, w, FabricSpec())
    ref = x @ w
    rel = np.linalg.norm(np.asarray(y - ref)) / np.linalg.norm(np.asarray(ref))
    assert rel < 0.02  # int8 quantization error budget


def test_imc_matmul_sim_noiseless_equals_exact():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(4, 24)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(24, 8)).astype(np.float32))
    ye = imc_matmul(x, w, FabricSpec(bits_a=4, bits_w=4))
    ys = imc_matmul(x, w, FabricSpec(bits_a=4, bits_w=4, mode="sim", backend="jnp"))
    np.testing.assert_allclose(np.asarray(ye), np.asarray(ys), rtol=1e-6)


def test_imc_matmul_sim_with_mismatch_bounded_error():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    y = imc_matmul(x, w, FabricSpec(mode="sim", backend="jnp",
                                    noise=NoiseSpec.calibrated()),
                   key=jax.random.key(0))
    ref = np.asarray(x @ w)
    rel = np.linalg.norm(np.asarray(y) - ref) / np.linalg.norm(ref)
    # Voltage-referred mismatch preserves decode margins (paper §IV-C):
    # occasional +-1 count flips at most, so the result stays accurate.
    assert rel < 0.05


def test_mismatch_flips_are_rare_but_possible():
    # With sigma_vk cranked up, decode errors MUST appear (sanity that the
    # noise is actually wired through); with the calibrated value they are
    # rare enough to keep exact == sim on small problems (paper margins).
    from repro.core.bitserial import decode_group_counts
    counts = jnp.full((4096,), 4, jnp.int32)
    noisy = decode_group_counts(counts, mode="sim", mismatch=True,
                                key=jax.random.key(3))
    calibrated_flips = int(np.sum(np.asarray(noisy) != 4))
    import repro.core.constants as C
    big = decode_group_counts(counts, mode="sim", mismatch=True,
                              key=jax.random.key(3), )
    assert calibrated_flips < 40  # < 1% at sigma_vk = 0.05
    # direct check that larger sigma produces flips
    from repro.core.montecarlo import mc_count_noise
    from repro.core.rbl import rbl_voltage
    from repro.core.decoder import decode_voltage
    k_eff = counts.astype(jnp.float32) + mc_count_noise(
        jax.random.key(4), counts.shape, counts, sigma_vk=0.5)
    dec = decode_voltage(rbl_voltage(k_eff))
    assert int(np.sum(np.asarray(dec) != 4)) > 100


def test_imc_matmul_use_kernel_matches_xla_path():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(24, 80)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(80, 40)).astype(np.float32))
    y_xla = imc_matmul(x, w, FabricSpec(backend="jnp"))
    y_ker = imc_matmul(x, w, FabricSpec(backend="pallas"))
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_ker), rtol=1e-6)


def test_int_matmul_int32_accumulation():
    qa = jnp.full((2, 512), 127, jnp.int8)
    qw = jnp.full((512, 2), 127, jnp.int8)
    out = np.asarray(int_matmul(qa, qw))
    assert out.dtype == np.int32
    np.testing.assert_array_equal(out, np.full((2, 2), 127 * 127 * 512))


def test_imc_matmul_cost_report():
    rep = imc_matmul_cost((128, 256), (256, 64), bits=8)
    # evaluations = M * ceil(K/8) * bits^2 * ceil(N/8)
    assert rep.evaluations == 128 * 32 * 64 * 8
    assert rep.energy_j > 0 and rep.latency_s > 0
    assert rep.macs == 128 * 256 * 64 * 64
    cold = imc_matmul_cost((128, 256), (256, 64), schedule="cold")
    assert cold.latency_s > rep.latency_s  # weight-stationary is faster


# --------------------------------------------------------------- imc_linear
def test_imc_linear_forward_and_grads():
    key = jax.random.key(0)
    p = init_imc_linear(key, 32, 16, use_bias=True)
    x = jax.random.normal(jax.random.key(1), (8, 32))

    def loss(params, x):
        y = apply_imc_linear(params, x)
        return jnp.sum(y * y)

    val, grads = jax.value_and_grad(loss)(p, x)
    assert np.isfinite(float(val))
    assert grads["w"].shape == (32, 16) and grads["b"].shape == (16,)
    assert np.all(np.isfinite(np.asarray(grads["w"])))
    # STE: grads match the float-matmul surrogate
    y = apply_imc_linear(p, x)
    np.testing.assert_allclose(np.asarray(grads["b"]),
                               np.asarray(2 * y.sum(0)), rtol=1e-4)
