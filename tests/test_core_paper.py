"""Paper-fidelity tests: the core model must reproduce Tables I-IV, Fig 5, Fig 6."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import constants as C
from repro.core import (ArraySpec, Timing, add_1bit, decode_voltage,
                        empty_state, level_voltages, logic2, logic_energy_fj,
                        logic_from_count, mac, mac_energy_fj, mc_stats,
                        rbl_voltage, read_bit, thermometer_code, write,
                        write_row)


# ----------------------------------------------------------------- Table I
def test_table1_lut_voltages_exact():
    ks = jnp.arange(9)
    np.testing.assert_allclose(rbl_voltage(ks, mode="lut"), C.V_RBL_TABLE,
                               atol=1e-6)


def test_table1_physics_fit_tolerance():
    ks = jnp.arange(9)
    v = rbl_voltage(ks, mode="physics")
    np.testing.assert_allclose(v, C.V_RBL_TABLE, atol=0.020)  # <= 20 mV


def test_voltage_monotone_decreasing():
    for mode in ("lut", "physics"):
        v = np.asarray(rbl_voltage(jnp.arange(9), mode=mode))
        assert np.all(np.diff(v) < 0)


def test_physics_scales_to_larger_arrays():
    # Paper §III-F: larger arrays shrink level spacing but keep ordering.
    v16 = np.asarray(rbl_voltage(jnp.arange(17), rows=16, mode="physics"))
    assert np.all(np.diff(v16) < 0)
    sp8 = -np.diff(np.asarray(rbl_voltage(jnp.arange(9), mode="physics")))
    sp16 = -np.diff(v16)
    assert sp16[0] < sp8[0]  # reduced spacing with bigger C_RBL


def test_table1_decoded_thermometer_codes():
    # Table I: k=0 -> 11111111 ... k=8 -> 00000000.
    v = rbl_voltage(jnp.arange(9), mode="lut")
    codes = thermometer_code(v)
    for k in range(9):
        assert int(codes[k].sum()) == 8 - k
    counts = decode_voltage(v)
    np.testing.assert_array_equal(counts, np.arange(9))


# ---------------------------------------------------------------- Table II
def test_table2_logic_interpretation():
    # Data patterns 00, 01, 10, 11 -> counts 0, 1, 1, 2.
    counts = jnp.array([0, 1, 1, 2])
    out = logic_from_count(counts, m=2)
    np.testing.assert_array_equal(out["AND"], [0, 0, 0, 1])
    np.testing.assert_array_equal(out["NOR"], [1, 0, 0, 0])
    np.testing.assert_array_equal(out["XOR"], [0, 1, 1, 0])
    np.testing.assert_array_equal(out["NAND"], [1, 1, 1, 0])
    np.testing.assert_array_equal(out["OR"], [0, 1, 1, 1])
    np.testing.assert_array_equal(out["XNOR"], [1, 0, 0, 1])
    s, c = add_1bit(counts)
    np.testing.assert_array_equal(s, [0, 1, 1, 0])
    np.testing.assert_array_equal(c, [0, 0, 0, 1])


def test_table2_voltages_match():
    v = rbl_voltage(jnp.array([0, 1, 1, 2]), mode="lut")
    np.testing.assert_allclose(v, [1.758, 1.528, 1.528, 1.308], atol=1e-6)


# --------------------------------------------------------------- Table III
def test_table3_energy_lut_exact():
    np.testing.assert_allclose(mac_energy_fj(jnp.arange(9)), C.E_MAC_TABLE_FJ,
                               atol=1e-4)


def test_table3_energy_fit():
    e = mac_energy_fj(jnp.arange(9), exact=False)
    # quadratic fit through the physics voltages: generous tolerance
    np.testing.assert_allclose(e, C.E_MAC_TABLE_FJ, atol=12.0)


def test_energy_monotone_in_count():
    e = np.asarray(mac_energy_fj(jnp.arange(9)))
    assert np.all(np.diff(e) > 0)


def test_energy_per_bit():
    assert abs(C.ENERGY_PER_BIT_FJ - 56.56) < 0.06  # paper: 56.56 fJ/bit


# ---------------------------------------------------------------- Table IV
def test_table4_logic_energies():
    assert logic_energy_fj("AND") == pytest.approx(212.7)
    assert logic_energy_fj("CARRY") == pytest.approx(212.7)
    assert logic_energy_fj("NOR") == pytest.approx(5.369)
    assert logic_energy_fj("XOR") == pytest.approx(119.3)
    assert logic_energy_fj("SUM") == pytest.approx(119.3)
    # complements consume the same evaluation
    assert logic_energy_fj("NAND") == pytest.approx(212.7)
    assert logic_energy_fj("OR") == pytest.approx(5.369)
    assert logic_energy_fj("XNOR") == pytest.approx(119.3)


# -------------------------------------------------------------- Fig 5 timing
def test_fig5_timing_model():
    t = Timing()
    assert t.t_op_s == pytest.approx(63e-9)
    assert t.throughput_ops == pytest.approx(15.87e6, rel=0.01)  # paper: 15.8 M
    assert t.f_clk_hz == pytest.approx(142.85e6, rel=0.001)
    assert t.t_eval_s == pytest.approx(0.7e-9)


# ---------------------------------------------------------- Fig 6 Monte-Carlo
def test_fig6_montecarlo_stats():
    mean, std = mc_stats(jax.random.key(0), k=8, n_samples=200_000)
    assert float(mean) == pytest.approx(C.MC_MEAN_FJ, rel=0.02)  # 437 fJ
    assert float(std) == pytest.approx(C.MC_STD_FJ, rel=0.05)  # 48.72 fJ


def test_fig6_paper_sample_count():
    # With the paper's own n=200, stats are within MC error of the target.
    mean, std = mc_stats(jax.random.key(1), k=8, n_samples=200)
    assert abs(float(mean) - C.MC_MEAN_FJ) < 15.0
    assert abs(float(std) - C.MC_STD_FJ) < 10.0


# ------------------------------------------------------------- array behavior
def test_array_write_read_roundtrip():
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, size=(8, 8)).astype(np.uint8)
    state = write(empty_state(), bits)
    for r in range(8):
        np.testing.assert_array_equal(read_bit(state, r), bits[r])


def test_array_write_row_cycles():
    state = empty_state()
    bits = np.eye(8, dtype=np.uint8)
    for r in range(8):  # 8 write cycles, as in Fig 5
        state = write_row(state, r, bits[r])
    np.testing.assert_array_equal(np.asarray(state), bits)


def test_array_mac_full_path():
    # Paper Fig 5 case: both operands 11111111 -> count 8, code 00000000.
    state = write(empty_state(), np.ones((8, 8), np.uint8))
    res = mac(state, np.ones(8, np.uint8))
    np.testing.assert_array_equal(res.counts, np.full(8, 8))
    np.testing.assert_array_equal(res.codes, np.zeros((8, 8), np.uint8))
    np.testing.assert_allclose(res.volts, np.full(8, 0.310), atol=1e-6)
    np.testing.assert_allclose(res.energy_fj, np.full(8, 452.2), atol=1e-3)


def test_array_mac_random_counts():
    rng = np.random.default_rng(3)
    for _ in range(20):
        b = rng.integers(0, 2, size=(8, 8)).astype(np.uint8)
        a = rng.integers(0, 2, size=8).astype(np.uint8)
        state = write(empty_state(), b)
        res = mac(state, a)
        np.testing.assert_array_equal(res.counts, (a[None].astype(int) @ b)[0])


def test_array_logic2_bitwise_8bit():
    # 8-bit bitwise ops: one bit per column (paper's 8-bit AND/NOR/XOR claim).
    rng = np.random.default_rng(7)
    wa = rng.integers(0, 2, size=8).astype(np.uint8)
    wb = rng.integers(0, 2, size=8).astype(np.uint8)
    state = write_row(write_row(empty_state(), 0, wa), 1, wb)
    out, res = logic2(state, 0, 1)
    np.testing.assert_array_equal(out["AND"], wa & wb)
    np.testing.assert_array_equal(out["OR"], wa | wb)
    np.testing.assert_array_equal(out["XOR"], wa ^ wb)
    np.testing.assert_array_equal(out["NOR"], 1 - (wa | wb))


def test_comparator_noise_within_margin():
    # Level spacing is 100-250 mV; a 10 mV comparator offset never misdecodes.
    v = rbl_voltage(jnp.arange(9), mode="lut")
    counts = decode_voltage(jnp.tile(v, (64, 1)), comparator_offset_sigma=0.010,
                            key=jax.random.key(2))
    np.testing.assert_array_equal(counts, np.tile(np.arange(9), (64, 1)))


def test_array_spec_validation():
    with pytest.raises(ValueError):
        ArraySpec(rows=16, mode="lut")
    ArraySpec(rows=16, mode="physics")  # fine
