"""flash_attn kernel vs oracle: shape/dtype/window sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import flash_attention_ref


def _mk(b, s, h, kv, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    return q, k, v


def _ref(q, k, v, window=0):
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    kf = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vf = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    o = flash_attention_ref(qf, kf, vf, scale=hd ** -0.5, window=window)
    return o.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("s", [8, 128, 160, 384])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref_shapes(s, dtype):
    q, k, v = _mk(2, s, 4, 2, 32, dtype)
    out = flash_attention(q, k, v, bq=128, bk=128, interpret=True)
    ref = _ref(q, k, v)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_sliding_window(window):
    q, k, v = _mk(1, 256, 4, 4, 16, jnp.float32, seed=1)
    out = flash_attention(q, k, v, window=window, interpret=True)
    ref = _ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-6, rtol=3e-6)


@pytest.mark.parametrize("bq,bk", [(64, 128), (128, 64), (256, 256)])
def test_flash_block_shape_sweep(bq, bk):
    q, k, v = _mk(1, 512, 8, 2, 64, jnp.float32, seed=2)
    out = flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
    ref = _ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-6, rtol=3e-6)


def test_flash_mha_no_gqa():
    q, k, v = _mk(2, 128, 4, 4, 32, jnp.float32, seed=3)
    out = flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_ref(q, k, v)),
                               atol=3e-6, rtol=3e-6)


def test_flash_path_end_to_end_model():
    """Full-model logits: flash kernel path vs chunked jnp path."""
    import dataclasses

    from repro.configs import get_config, reduce_config
    from repro.models import forward_logits, init_params

    cfg = reduce_config(get_config("qwen2.5-3b"))
    cfg_f = dataclasses.replace(cfg, use_flash_kernel=True)
    params = init_params(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 32), 0,
                                          cfg.vocab_size)}
    a = np.asarray(forward_logits(params, batch, cfg), np.float32)
    b = np.asarray(forward_logits(params, batch, cfg_f), np.float32)
    rel = np.linalg.norm(a - b) / np.linalg.norm(a)
    assert rel < 0.02  # bf16 accumulation-order differences only
