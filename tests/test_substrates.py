"""Substrate tests: data pipeline, optimizer, checkpoint, fault tolerance,
compression, elastic planning, straggler policy."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step,
                                         restore, save)
from repro.data.pipeline import DataConfig, SyntheticStream, validate_determinism
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw, lr_schedule
from repro.runtime.compression import compress, decompress, init_compression
from repro.runtime.elastic import plan_mesh, shrink_after_failure
from repro.runtime.fault_tolerance import FaultTolerantLoop, InjectedFailure
from repro.runtime.straggler import StragglerMonitor


# ------------------------------------------------------------------- data
def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    assert validate_determinism(cfg)
    s = SyntheticStream(cfg)
    full = s.batch(3, 0, 1)
    parts = [s.batch(3, i, 4) for i in range(4)]
    assert parts[0]["tokens"].shape == (2, 16)
    # different shards differ; same shard reproduces
    assert not np.array_equal(parts[0]["tokens"], parts[1]["tokens"])
    np.testing.assert_array_equal(np.asarray(s.batch(3, 1, 4)["tokens"]),
                                  np.asarray(parts[1]["tokens"]))
    # labels are the shifted stream (learnable next-token signal)
    assert full["labels"].shape == (8, 16)


def test_data_rejects_bad_shard_counts():
    s = SyntheticStream(DataConfig(100, 8, 8))
    with pytest.raises(ValueError):
        s.batch(0, 0, 3)


# ------------------------------------------------------------------ optim
def test_adamw_descends_quadratic():
    w = {"w": jnp.asarray([3.0, -2.0])}
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, grad_clip=10.0)
    state = init_adamw(w)
    for _ in range(100):
        g = {"w": 2 * state.master["w"]}  # d/dw ||w||^2
        w, state, metrics = adamw_update(g, state, cfg,
                                         param_dtype=jnp.float32)
    assert float(jnp.abs(state.master["w"]).max()) < 0.3
    assert np.isfinite(float(metrics["grad_norm"]))


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6
    assert lrs[100] == pytest.approx(0.1, rel=1e-3)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # decay


def test_adamw_bf16_params_fp32_master():
    w = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init_adamw(w)
    assert state.master["w"].dtype == jnp.float32
    new_w, state, _ = adamw_update({"w": jnp.ones((4,), jnp.bfloat16)},
                                   state, AdamWConfig())
    assert new_w["w"].dtype == jnp.bfloat16


# ------------------------------------------------------------- checkpoint
def _tree():
    return {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 3), jnp.bfloat16),
                  "d": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    root = str(tmp_path / "ckpt")
    t = _tree()
    save(root, 5, t)
    out, step = restore(root, jax.tree.map(jnp.zeros_like, t))
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest(tmp_path):
    root = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4):
        save(root, s, _tree(), keep_last=2)
    assert latest_step(root) == 4
    kept = sorted(os.listdir(root))
    assert len([k for k in kept if k.startswith("step_")]) == 2


def test_checkpoint_async(tmp_path):
    root = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(root)
    ck.save_async(1, _tree())
    ck.wait()
    assert latest_step(root) == 1


def test_checkpoint_ignores_uncommitted(tmp_path):
    root = str(tmp_path / "ckpt")
    save(root, 1, _tree())
    # fake a torn checkpoint at a later step
    os.makedirs(os.path.join(root, "step_000000002"))
    assert latest_step(root) == 1


# --------------------------------------------------------- fault tolerance
def test_fault_tolerant_restart_bit_exact(tmp_path):
    root = str(tmp_path / "ft")
    stream = SyntheticStream(DataConfig(97, 8, 4))

    def step_fn(state, batch, step):
        return {"w": state["w"] + jnp.sum(batch["tokens"]) % 13,
                "n": state["n"] + 1}

    def batch_fn(step):
        return stream.batch(step)

    init = {"w": jnp.float32(0), "n": jnp.int32(0)}

    # uninterrupted reference
    ref = FaultTolerantLoop(root + "_ref", step_fn, batch_fn,
                            ckpt_every=3).run(init, 10)
    # crash at step 7, then restart
    loop = FaultTolerantLoop(root, step_fn, batch_fn, ckpt_every=3,
                             fail_at={7})
    with pytest.raises(InjectedFailure):
        loop.run(init, 10)
    out = loop.run(init, 10)  # resumes from latest committed step
    assert int(out["n"]) == 10
    assert float(out["w"]) == float(ref["w"])


# -------------------------------------------------------------- compression
def test_compression_error_feedback_converges():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=512),
                          jnp.float32)}
    state = init_compression(g)
    acc_plain = jnp.zeros(512)
    acc_comp = jnp.zeros(512)
    for _ in range(50):
        (q, s), state = compress(g, state)
        acc_comp = acc_comp + decompress(q, s)["w"]
        acc_plain = acc_plain + g["w"]
    rel = float(jnp.linalg.norm(acc_comp - acc_plain)
                / jnp.linalg.norm(acc_plain))
    assert rel < 0.01  # error feedback keeps the accumulated sum unbiased


def test_compression_bytes_ratio():
    g = {"w": jnp.zeros((1024,), jnp.float32)}
    (q, s), _ = compress(g, init_compression(g))
    assert q["w"].dtype == jnp.int8  # 4x fewer bytes than f32 on the wire


# ------------------------------------------------------------------ elastic
def test_elastic_plans():
    p = plan_mesh(512, model_parallel=16, base_batch=256)
    assert p.shape == (2, 16, 16) and p.axes == ("pod", "data", "model")
    p2 = shrink_after_failure(p, lost_devices=256, model_parallel=16)
    assert p2.n_devices == 256 and p2.shape == (16, 16)
    # per-replica batch preserved
    assert p2.global_batch * 2 == p.global_batch
    with pytest.raises(ValueError):
        plan_mesh(8, model_parallel=16, base_batch=64)


# ---------------------------------------------------------------- straggler
def test_straggler_detection_and_swap():
    mon = StragglerMonitor()
    for step in range(6):
        times = {h: 1.0 for h in range(8)}
        times[3] = 3.0  # persistent straggler
        swaps = mon.record_step(times)
    assert 3 in mon.swaps
    mon.replace_host(3)
    # stats are dropped, not zeroed: the EWMA re-seeds from the replacement
    # host's first real sample (full semantics pinned in test_telemetry.py)
    assert 3 not in mon.hosts
    # healthy fleet: no swaps
    mon2 = StragglerMonitor()
    for _ in range(6):
        assert mon2.record_step({h: 1.0 + 0.01 * h for h in range(8)}) == []
