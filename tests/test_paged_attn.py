"""Paged flash-decode kernel tests: parity vs the jnp gather oracle + wiring.

The kernel (``kernels/paged_attn``) must reproduce the dense-gather oracle
(``ref.paged_decode_ref`` — the exact pre-kernel serving computation) across
bf16/int8 pools, GQA, sliding windows, and ragged block tables with ``-1``
sentinel rows and partially-filled last blocks.  Expected agreement:

  * f32 pools: ~1e-6 (same f32 contraction, different-but-benign reduction
    grouping across blocks).
  * bf16 pools: within ~2 output ulp.  Exact bit-equality is unattainable in
    principle: online softmax rescales past contributions by exp(m_old-m_new)
    while one-shot softmax exponentiates once, so the two round differently.
    The serving default (``attn_impl="jnp"``) remains the bit-exact path.
  * int8 pools: atol 1e-2 (quantization noise dominates; the kernel
    dequantizes in-register, the oracle pre-dequantizes — same scales).

Everything runs in Pallas interpret mode on CPU (``interpret=None`` resolves
via ``kernels.compat``), so CI exercises the kernel body on every PR.

Wiring tests pin the end-to-end story: ``attn_decode(attn_impl=...)`` parity
at the layer level with a shared (bit-identical) cache scatter, ModelConfig
validation, Server impl selection, zero steady-state retraces through the
Server with ``attn_impl="pallas"``, and flash prefill (``use_flash``)
producing the same decode cache bit-for-bit.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.kernels.paged_attn.ops import paged_attention
from repro.launch.engine import Engine
from repro.launch.server import Request, Server
from repro.models.attention import (AttnCache, PagedAttnCache, _kv_quant,
                                    attn_decode, attn_prefill, init_attention)
from repro.models.model import init_params

ATOL = {"f32": 5e-6, "bf16": 1.6e-2, "int8": 1e-2}


def _pools(rng, nb, bs, kv, hd, dtype=jnp.float32):
    k = jnp.asarray(rng.standard_normal((nb, bs, kv, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((nb, bs, kv, hd)), dtype)
    return k, v


def _ragged(rng, pos, mb, nb, bs):
    """Dense-prefix tables covering each row's pos; partially-filled last
    blocks whenever pos+1 is not a block multiple; -1 sentinels after."""
    b = len(pos)
    tbl = np.full((b, mb), -1, np.int32)
    perm = iter(rng.permutation(nb))
    for i, p in enumerate(pos):
        for j in range(p // bs + 1):
            tbl[i, j] = next(perm)
    return jnp.asarray(tbl)


def _err(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))


# ------------------------------------------------------------- op-level parity
@pytest.mark.parametrize("dtype", ["f32", "bf16"])
@pytest.mark.parametrize("window", [0, 9])
@pytest.mark.parametrize("kv_heads", [4, 2, 1])  # H=4: MHA, GQA, MQA
def test_kernel_matches_oracle(dtype, window, kv_heads):
    rng = np.random.default_rng(0)
    B, H, hd, bs, nb, mb = 3, 4, 16, 8, 14, 4
    jdt = {"f32": jnp.float32, "bf16": jnp.bfloat16}[dtype]
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jdt)
    kp, vp = _pools(rng, nb, bs, kv_heads, hd, jdt)
    pos = np.array([5, 17, 26])  # straddles block edges, partial last blocks
    tbl = _ragged(rng, pos, mb, nb, bs)
    kw = dict(window=window)
    ref = paged_attention(q, kp, vp, tbl, jnp.asarray(pos), impl="jnp", **kw)
    out = paged_attention(q, kp, vp, tbl, jnp.asarray(pos), impl="pallas", **kw)
    assert out.dtype == q.dtype and out.shape == q.shape
    assert _err(ref, out) <= ATOL[dtype], (dtype, window, kv_heads)


@pytest.mark.parametrize("window", [0, 9])
def test_kernel_matches_oracle_int8(window):
    rng = np.random.default_rng(1)
    B, H, KV, hd, bs, nb, mb = 3, 4, 2, 16, 8, 14, 4
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.bfloat16)
    kf, vf = _pools(rng, nb, bs, KV, hd)
    kq, ks = _kv_quant(kf)
    vq, vs = _kv_quant(vf)
    pos = np.array([5, 17, 26])
    tbl = _ragged(rng, pos, mb, nb, bs)
    kw = dict(k_scale=ks, v_scale=vs, window=window)
    ref = paged_attention(q, kq, vq, tbl, jnp.asarray(pos), impl="jnp", **kw)
    out = paged_attention(q, kq, vq, tbl, jnp.asarray(pos), impl="pallas", **kw)
    assert _err(ref, out) <= ATOL["int8"]


def test_kernel_inactive_slot_is_finite():
    """A slot with an all-sentinel table (nothing admitted) must not poison
    the batch: the kernel flushes exact zeros, the oracle garbage — both
    unused, but NaN/inf would taint downstream reductions."""
    rng = np.random.default_rng(2)
    B, H, hd, bs, nb, mb = 2, 4, 16, 8, 10, 3
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    kp, vp = _pools(rng, nb, bs, 2, hd)
    pos = np.array([13, 0])
    tbl = _ragged(rng, pos, mb, nb, bs).at[1].set(-1)
    out = paged_attention(q, kp, vp, tbl, jnp.asarray(pos), impl="pallas")
    ref = paged_attention(q, kp, vp, tbl, jnp.asarray(pos), impl="jnp")
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(out[1] == 0))
    assert _err(ref[0], out[0]) <= ATOL["f32"]  # active row still matches


def test_kernel_single_and_full_tables():
    """Degenerate geometries: one block per slot, and a completely full
    table (pos on the last row of the last block)."""
    rng = np.random.default_rng(3)
    B, H, hd, bs, nb = 2, 2, 8, 4, 6
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    kp, vp = _pools(rng, nb, bs, 2, hd)
    for mb, pos in ((1, [0, 3]), (3, [11, 7])):
        tbl = _ragged(rng, np.asarray(pos), mb, nb, bs)
        ref = paged_attention(q, kp, vp, tbl, jnp.asarray(pos), impl="jnp")
        out = paged_attention(q, kp, vp, tbl, jnp.asarray(pos), impl="pallas")
        assert _err(ref, out) <= ATOL["f32"]


@pytest.mark.parametrize("bps", [2, 3, 4, 8])
def test_kernel_bit_identical_across_blocks_per_step(bps):
    """The multi-block-per-grid-step variant packs bps pool-panel DMAs into
    one step but walks blocks in the same order, so it must be BIT-identical
    to bps=1 — on f32 pools, int8 pools, windows, and ragged tables (incl.
    the mb % bps tail)."""
    rng = np.random.default_rng(5)
    B, H, KV, hd, bs, nb, mb = 3, 4, 2, 16, 8, 22, 7
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    kp, vp = _pools(rng, nb, bs, KV, hd)
    pos = np.array([5, 30, 51])
    tbl = _ragged(rng, pos, mb, nb, bs)
    for kw in (dict(), dict(window=9)):
        base = paged_attention(q, kp, vp, tbl, jnp.asarray(pos),
                               impl="pallas", blocks_per_step=1, **kw)
        out = paged_attention(q, kp, vp, tbl, jnp.asarray(pos),
                              impl="pallas", blocks_per_step=bps, **kw)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(out))
    kq, ks = _kv_quant(kp)
    vq, vs = _kv_quant(vp)
    kw = dict(k_scale=ks, v_scale=vs)
    base = paged_attention(q, kq, vq, tbl, jnp.asarray(pos), impl="pallas",
                           blocks_per_step=1, **kw)
    out = paged_attention(q, kq, vq, tbl, jnp.asarray(pos), impl="pallas",
                          blocks_per_step=bps, **kw)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


def test_op_rejects_unknown_impl():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((1, 1, 2, 8)), jnp.float32)
    kp, vp = _pools(rng, 2, 4, 2, 8)
    tbl = jnp.zeros((1, 1), jnp.int32)
    with pytest.raises(ValueError, match="impl"):
        paged_attention(q, kp, vp, tbl, jnp.asarray([0]), impl="tpu")


def test_kernel_parity_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    B, H, KV, hd, bs, mb = 3, 4, 2, 8, 4, 4
    nb = B * mb + 2
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    kp, vp = _pools(rng, nb, bs, KV, hd)

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(st.lists(st.integers(0, mb * bs - 1), min_size=B, max_size=B),
               st.integers(0, 2 ** 31 - 1),
               st.sampled_from([0, 3, 10]))
    def run(pos, seed, window):
        tbl = _ragged(np.random.default_rng(seed), np.asarray(pos), mb, nb, bs)
        p = jnp.asarray(pos)
        ref = paged_attention(q, kp, vp, tbl, p, window=window, impl="jnp")
        out = paged_attention(q, kp, vp, tbl, p, window=window, impl="pallas")
        assert _err(ref, out) <= ATOL["f32"]

    run()


# ---------------------------------------------------------- layer-level wiring
@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_attn_decode_paged_impl_parity(kv_dtype):
    """Through ``attn_decode``: both impls share one scatter (caches must be
    bit-identical) and agree on the mixed output within kernel tolerance."""
    rng = np.random.default_rng(6)
    d, H, KV, hd, bs, nb, mb, B = 32, 4, 2, 8, 4, 10, 3, 3
    params = init_attention(jax.random.key(0), d, H, KV, hd,
                            dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, 1, d)), jnp.float32)
    pos = jnp.asarray([5, 9, 2])
    tbl = _ragged(rng, np.asarray(pos), mb, nb, bs)
    if kv_dtype == "int8":
        kq, ks = _kv_quant(jnp.asarray(
            rng.standard_normal((nb, bs, KV, hd)), jnp.float32))
        vq, vs = _kv_quant(jnp.asarray(
            rng.standard_normal((nb, bs, KV, hd)), jnp.float32))
        cache = PagedAttnCache(kq, vq, ks, vs)
    else:
        kp, vp = _pools(rng, nb, bs, KV, hd)
        cache = PagedAttnCache(kp, vp)
    kw = dict(n_heads=H, n_kv_heads=KV, head_dim=hd, rope_theta=1e4,
              block_table=tbl)
    y_j, c_j = attn_decode(params, x, cache, pos, attn_impl="jnp", **kw)
    y_p, c_p = attn_decode(params, x, cache, pos, attn_impl="pallas", **kw)
    for a, b in zip(jax.tree.leaves(c_j), jax.tree.leaves(c_p)):
        assert jnp.array_equal(a, b), "impl switch changed the cache scatter"
    tol = {"bf16": 5e-5, "int8": 1e-2}[kv_dtype]  # f32 activations
    assert _err(y_j, y_p) <= tol


def test_model_config_validates_attn_impl():
    cfg = reduce_config(get_config("qwen2.5-3b"))
    with pytest.raises(ValueError, match="attn_impl"):
        dataclasses.replace(cfg, attn_impl="cuda")
    assert dataclasses.replace(cfg, attn_impl="pallas").attn_impl == "pallas"


# --------------------------------------------------------------- server-level
LENGTHS = (7, 16, 33)
MAX_NEW = 4


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("qwen2.5-3b"))


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.key(0), cfg)


def _serve_wave(server, cfg, rng):
    hs = [server.submit(Request(
        rng.integers(0, cfg.vocab_size, size=n).astype(np.int32),
        max_new_tokens=MAX_NEW)) for n in LENGTHS]
    server.drain()
    assert all(h.done and len(h.tokens) == MAX_NEW for h in hs)
    return hs


def test_server_attn_impl_selection(cfg, params):
    eng = Engine()
    with eng.activate():
        srv = Server(cfg, params, engine=eng, slots=2, block_size=8,
                     buckets=(16,), attn_impl="pallas", max_seq_len=24)
        assert srv.attn_impl == "pallas"
        assert srv.cfg.attn_impl == "pallas"  # carried in Engine cache keys
        # default: kernel on TPU, else keep the config's (jnp) path — the
        # interpreter is opt-in, never a silent serving default
        expect = ("pallas" if jax.default_backend() == "tpu"
                  else cfg.attn_impl)
        assert Server(cfg, params, engine=eng, slots=2, block_size=8,
                      buckets=(16,), max_seq_len=24).attn_impl == expect
        # the ring geometry has no paged engine to select
        assert Server(cfg, params, engine=eng, slots=2, kv="ring",
                      buckets=(16,), max_seq_len=24).attn_impl == "ring"


def test_server_pallas_zero_steady_state_retraces(cfg, params):
    """Two identical ragged waves through attn_impl='pallas' (+ flash
    prefill): wave 2 must reuse every compiled step — the kernel rides inside
    the jitted decode step without adding trace keys."""
    c = dataclasses.replace(cfg, use_flash_kernel=True)
    eng = Engine()
    rng = np.random.default_rng(0)
    with eng.activate():
        srv = Server(c, params, engine=eng, slots=2, block_size=8,
                     buckets=(16, 48), attn_impl="pallas",
                     max_seq_len=48 + MAX_NEW)
        _serve_wave(srv, c, rng)
        warm = eng.stats.traces
        _serve_wave(srv, c, rng)
        assert eng.stats.traces == warm, \
            f"steady-state retrace: {warm} -> {eng.stats.traces}"
    from repro.telemetry import serving_slos

    slos = serving_slos(eng.registry, attn_impl=srv.attn_impl)
    assert slos["attn_impl"] == "pallas"
    assert slos["ttft_ms"] is not None and slos["tpot_ms"] is not None


# ------------------------------------------------------------- flash prefill
@pytest.mark.parametrize("window", [0, 16])
def test_flash_prefill_matches_chunked(window):
    """``use_flash`` prefill: same decode cache bit-for-bit (the cache is
    built from the projections, not the mixed output) and the mixed output
    within flash tolerance — including a right-padded ragged prompt."""
    rng = np.random.default_rng(7)
    d, H, KV, hd, S = 32, 4, 2, 8, 24
    params = init_attention(jax.random.key(1), d, H, KV, hd,
                            dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, S, d)), jnp.float32)
    kw = dict(n_heads=H, n_kv_heads=KV, head_dim=hd, rope_theta=1e4,
              window=window, cache_len=S, true_len=jnp.asarray(17))
    y0, c0 = attn_prefill(params, x, use_flash=False, **kw)
    y1, c1 = attn_prefill(params, x, use_flash=True, **kw)
    assert isinstance(c1, AttnCache)
    for a, b in zip(jax.tree.leaves(c0), jax.tree.leaves(c1)):
        assert jnp.array_equal(a, b), "flash prefill changed the cache"
    # valid (non-padded) rows agree; padded-tail rows are never consumed
    assert _err(y0[:, :17], y1[:, :17]) <= 2e-4
