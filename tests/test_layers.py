"""Layer-level correctness: MoE vs dense reference, SSD vs naive recurrence,
RG-LRU parallel-scan vs sequential, attention chunking/window equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attn_decode, attn_forward, attn_prefill, init_attention
from repro.models.moe import apply_moe, init_moe
from repro.models.rglru import init_rglru, rglru_decode, rglru_forward
from repro.models.ssd import init_ssd, ssd_decode, ssd_forward


# -------------------------------------------------------------------- MoE
def _moe_dense_reference(params, x, n_experts, top_k, kind="swiglu"):
    """Loop-over-experts reference (no capacity dropping)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, top_k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros_like(xf, shape=(xf.shape[0], d), dtype=jnp.float32)
    for e in range(n_experts):
        w1 = params["w_gate"][e].astype(x.dtype)
        w3 = params["w_up"][e].astype(x.dtype)
        w2 = params["w_down"][e].astype(x.dtype)
        h = (jax.nn.silu(xf @ w1) * (xf @ w3)) @ w2
        gate = jnp.sum(jnp.where(idx == e, vals, 0.0), axis=-1)
        y = y + gate[:, None] * h.astype(jnp.float32)
    return y.reshape(b, s, d)


def test_moe_matches_dense_reference_no_drops():
    key = jax.random.key(0)
    d, f, e, k = 16, 32, 4, 2
    params = init_moe(key, d, f, e, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, d), jnp.float32)
    y, aux = apply_moe(params, x, n_experts=e, top_k=k,
                       capacity_factor=8.0)  # no dropping
    ref = _moe_dense_reference(params, x, e, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)
    assert float(aux["load_balance_loss"]) > 0


def test_moe_capacity_drops_degrade_gracefully():
    key = jax.random.key(0)
    d, f, e, k = 8, 16, 4, 2
    params = init_moe(key, d, f, e, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 64, d), jnp.float32)
    y_full, _ = apply_moe(params, x, n_experts=e, top_k=k, capacity_factor=8.0)
    y_tight, _ = apply_moe(params, x, n_experts=e, top_k=k,
                           capacity_factor=0.5)
    # tight capacity drops tokens but must stay finite and not explode
    assert np.all(np.isfinite(np.asarray(y_tight)))
    assert float(jnp.linalg.norm(y_tight)) <= 2 * float(jnp.linalg.norm(y_full))


def test_moe_grads_flow_to_router_and_experts():
    key = jax.random.key(0)
    d, f, e, k = 8, 16, 4, 2
    params = init_moe(key, d, f, e, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 16, d), jnp.float32)

    def loss(p):
        y, aux = apply_moe(p, x, n_experts=e, top_k=k)
        return jnp.sum(y * y) + 0.01 * aux["load_balance_loss"]

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]["w"]).max()) > 0
    assert float(jnp.abs(g["w_down"]).max()) > 0


# -------------------------------------------------------------------- SSD
def _ssd_naive(x, dt, a_neg, B, C):
    """Token-by-token reference recurrence."""
    bt, s, h, p = x.shape
    n = B.shape[-1]
    rep = h // B.shape[2]
    Bh = np.repeat(np.asarray(B, np.float64), rep, axis=2)
    Ch = np.repeat(np.asarray(C, np.float64), rep, axis=2)
    xs = np.asarray(x, np.float64)
    dts = np.asarray(dt, np.float64)
    an = np.asarray(a_neg, np.float64)
    hstate = np.zeros((bt, h, p, n))
    ys = np.zeros_like(xs)
    for t in range(s):
        dec = np.exp(dts[:, t] * an[None])  # (bt,h)
        hstate = (dec[..., None, None] * hstate
                  + np.einsum("bh,bhn,bhp->bhpn", dts[:, t], Bh[:, t], xs[:, t]))
        ys[:, t] = np.einsum("bhn,bhpn->bhp", Ch[:, t], hstate)
    return ys, hstate


def test_ssd_chunked_matches_naive_recurrence():
    from repro.models.ssd import _ssd_chunked

    rng = np.random.default_rng(0)
    bt, s, h, p, n = 2, 24, 4, 8, 16
    x = jnp.asarray(rng.normal(size=(bt, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(bt, s, h)).astype(np.float32))
    a_neg = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(bt, s, 1, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(bt, s, 1, n)).astype(np.float32))
    y, hl = _ssd_chunked(x, dt, a_neg, B, C, chunk=8)
    y_ref, h_ref = _ssd_naive(x, dt, a_neg, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), h_ref, rtol=1e-4, atol=1e-4)


def test_ssd_prefill_then_decode_matches_forward():
    key = jax.random.key(0)
    d = 32
    params = init_ssd(key, d, expand=2, headdim=8, state=16)
    x = jax.random.normal(jax.random.key(1), (2, 12, d), jnp.float32)
    full, _ = ssd_forward(params, x, expand=2, headdim=8, state=16, chunk=4)
    part, cache = ssd_forward(params, x[:, :11], expand=2, headdim=8,
                              state=16, chunk=11)
    last, _ = ssd_decode(params, x[:, 11:12], cache, expand=2, headdim=8,
                         state=16)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, 11]), rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------------ RG-LRU
def test_rglru_forward_matches_stepwise_decode():
    key = jax.random.key(0)
    d, w = 16, 24
    params = init_rglru(key, d, w)
    x = jax.random.normal(jax.random.key(1), (2, 10, d), jnp.float32)
    y_full, (h_last, conv) = rglru_forward(params, x)
    # replay the same sequence through the decode path
    h = jnp.zeros((2, w), jnp.float32)
    cs = jnp.zeros((2, 3, w), jnp.float32)
    outs = []
    for t in range(10):
        y, (h, cs) = rglru_decode(params, x[:, t:t + 1], h, cs)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_last),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------- attention
def _attn_kw(h=4, kv=2, hd=8):
    return dict(n_heads=h, n_kv_heads=kv, head_dim=hd, rope_theta=1e4)


def test_attention_chunked_equals_unchunked():
    key = jax.random.key(0)
    kw = _attn_kw()
    params = init_attention(key, 32, 4, 2, 8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 64, 32), jnp.float32)
    y1 = attn_forward(params, x, q_chunk=64, **kw)  # single chunk
    y2 = attn_forward(params, x, q_chunk=16, **kw)  # 4 chunks
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)


def test_window_attention_equals_masked_full():
    key = jax.random.key(0)
    kw = _attn_kw()
    params = init_attention(key, 32, 4, 2, 8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 48, 32), jnp.float32)
    # windowed path with chunk slicing vs window via full-mask path
    y_win = attn_forward(params, x, window=8, q_chunk=8, **kw)
    y_full = attn_forward(params, x, window=8, q_chunk=48, **kw)
    np.testing.assert_allclose(np.asarray(y_win), np.asarray(y_full),
                               rtol=2e-4, atol=2e-5)


def test_attention_decode_ring_buffer_window_semantics():
    key = jax.random.key(0)
    kw = _attn_kw()
    params = init_attention(key, 32, 4, 2, 8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 40, 32), jnp.float32)
    window = 8
    # teacher-forced reference
    ref = attn_forward(params, x, window=window, q_chunk=40, **kw)
    # prefill 32, then decode 8 steps with the ring cache
    _, cache = attn_prefill(params, x[:, :32], window=window, **kw)
    assert cache.k.shape[1] == window  # ring allocation = window
    for t in range(32, 40):
        y, cache = attn_decode(params, x[:, t:t + 1], cache,
                               jnp.int32(t), window=window, **kw)
        np.testing.assert_allclose(np.asarray(y[:, 0]),
                                   np.asarray(ref[:, t]),
                                   rtol=2e-3, atol=2e-4)
