"""Unit tests for the HLO call-graph cost model (no compilation needed)."""
from repro.launch.hlo_costs import HloCostModel, analyze

MODULE = """\
HloModule jit_f, is_scheduled=true

%fused_computation (param_0.3: f32[8,16]) -> f32[8,16] {
  %param_0.3 = f32[8,16]{1,0} parameter(0)
  %dot.9 = f32[8,16]{1,0} dot(%param_0.3, %param_0.3), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %add.1 = f32[8,16]{1,0} add(%dot.9, %param_0.3)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %gte = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

%body (p.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p.1 = (s32[], f32[8,16]) parameter(0)
  %gte.1 = s32[] get-tuple-element(%p.1), index=0
  %gte.2 = f32[8,16]{1,0} get-tuple-element(%p.1), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%gte.2, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,16]{1,0} all-gather(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  %one = s32[] constant(1)
  %next = s32[] add(%gte.1, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%next, %ag)
}

ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %fus = f32[8,16]{1,0} fusion(%arg), kind=kLoop, calls=%fused_computation
  %init_i = s32[] constant(0)
  %tup = (s32[], f32[8,16]) tuple(%init_i, %fus)
  %loop = (s32[], f32[8,16]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_parse_structure():
    m = HloCostModel(MODULE)
    assert m.entry == "main"
    assert set(m.comps) >= {"main", "body", "cond", "fused_computation"}


def test_flops_with_trip_multiplication():
    c = analyze(MODULE)
    # fusion-internal dot: 2*8*16*16 = 4096; loop dot: 4096 * 5 trips
    assert c.flops == 4096 + 4096 * 5


def test_collective_trip_scaled():
    c = analyze(MODULE)
    # all-gather operand f32[8,16] = 512 B, x5 trips
    assert c.coll_by_type["all-gather"] == 512 * 5
    assert c.coll_bytes == 512 * 5


def test_trip_count_fallback_from_condition():
    # strip the backend_config -> falls back to the cond constant (5)
    stripped = MODULE.replace(
        ', backend_config={"known_trip_count":{"n":"5"}}', "")
    c = analyze(stripped)
    assert c.flops == 4096 + 4096 * 5


def test_hbm_excludes_fusion_internals():
    c = analyze(MODULE)
    # fusion result (512B) + arg operand (512B) counted; dot in loop:
    # result 512 + operands (512 + 16*16*4=1024), x5; fusion-internal add: 0
    assert c.hbm_bytes >= 512 + 512 + 5 * (512 + 512 + 1024)
    assert c.hbm_bytes < 20000  # and nothing absurd


def test_int8_dot_classification():
    mod = MODULE.replace("f32[8,16]", "s8[8,16]").replace(
        "f32[16,16]", "s8[16,16]")
    c = analyze(mod)
    assert c.flops_int8 == c.flops > 0
