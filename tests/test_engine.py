"""Engine runtime tests: compiled-step cache, noise-key threading, and the
continuous-batching serve loop (slot surgery vs sequential decode, steady-state
recompile freedom, chaos-drill recovery, straggler hook)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.configs.base import ShapeConfig
from repro.core.fabric import FabricSpec, NoiseSpec
from repro.launch.compat import ambient_mesh, mesh_context
from repro.launch.engine import Engine
from repro.launch.mesh import make_test_mesh
from repro.launch.server import Request, Server
from repro.models.model import decode_step, init_params, prefill
from repro.optim.adamw import AdamWConfig, init_adamw
from repro.runtime.straggler import StragglerMonitor

MAX_NEW = 6
PROMPT = 16


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("qwen2.5-3b"))


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.key(0), cfg)


def _requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(0, cfg.vocab_size,
                                 size=PROMPT).astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for _ in range(n)]


def _ring_server(cfg, params, eng, **kw):
    """Fixed-ring serving geometry (the pre-paging shape) behind Server."""
    return Server(cfg, params, engine=eng, slots=2, kv="ring", **kw)


# ----------------------------------------------------------- compat shim
def test_mesh_context_installs_ambient_mesh():
    assert ambient_mesh() is None
    mesh = make_test_mesh()
    with mesh_context(mesh):
        amb = ambient_mesh()
        assert amb is not None
        assert tuple(amb.axis_names) == ("data", "model")
    assert ambient_mesh() is None


# ---------------------------------------------------- compiled-step cache
def test_compiled_step_cache_returns_same_executable(cfg):
    eng = Engine()
    d1 = eng.decode_step(cfg)
    d2 = eng.decode_step(cfg)
    assert d1 is d2
    assert eng.stats.compiles == 1 and eng.stats.hits == 1

    # equal-but-distinct ModelConfig values hit the same entry
    cfg_copy = dataclasses.replace(cfg)
    assert cfg_copy is not cfg
    assert eng.decode_step(cfg_copy) is d1
    assert eng.stats.compiles == 1 and eng.stats.hits == 2

    # a different FabricSpec is a different executable
    other = dataclasses.replace(cfg, fabric=FabricSpec(mode="exact"),
                                imc_mode="off")
    assert eng.decode_step(other) is not d1
    assert eng.stats.compiles == 2

    # kinds and prefill extras are distinct cache entries, stable per key
    p1 = eng.prefill_step(cfg, max_new_tokens=4)
    assert eng.prefill_step(cfg, max_new_tokens=4) is p1
    assert eng.prefill_step(cfg, max_new_tokens=8) is not p1
    t1 = eng.train_step(cfg, AdamWConfig(lr=1e-3))
    assert eng.train_step(cfg, AdamWConfig(lr=1e-3)) is t1
    assert eng.train_step(cfg, AdamWConfig(lr=2e-3)) is not t1


def test_aot_compile_cell(cfg):
    eng = Engine()
    shape = ShapeConfig("tiny_train", 32, 2, "train")
    aot = eng.aot_compile(cfg, shape)
    assert aot.compiled.memory_analysis() is not None
    shape_d = ShapeConfig("tiny_decode", 32, 2, "decode")
    aot_d = eng.aot_compile(cfg, shape_d)
    assert aot_d.compiled is not None


# --------------------------------------------- continuous-batching serve
def _sequential_decode(cfg, params, req):
    """Unbatched (B=1) greedy reference for one request."""
    logits, cache = prefill(params, {"tokens": jnp.asarray(req.prompt[None])},
                            cfg, max_new_tokens=MAX_NEW)
    out = [int(jnp.argmax(logits[0]))]
    while len(out) < MAX_NEW:
        logits, cache = decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32), cfg)
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_batched_serve_matches_sequential_decode(cfg, params):
    reqs = _requests(cfg, 5)
    eng = Engine()
    with eng.activate():
        server = _ring_server(cfg, params, eng)
        handles = [server.submit(r) for r in reqs]
        server.drain()
    for h in handles:
        assert h.tokens == _sequential_decode(cfg, params, h.request), \
            f"req{h.rid}: batched stream diverged from sequential decode"


def test_serve_steady_state_no_recompiles(cfg, params):
    eng = Engine()
    with eng.activate():
        server = _ring_server(cfg, params, eng)
        server.submit(_requests(cfg, 1)[0])
        server.drain()  # warm every executable (prefill/admit/decode)
        warm = eng.stats.traces
        for r in _requests(cfg, 4, seed=1):
            server.submit(r)
        handles = server.drain()
    assert all(len(h.tokens) == MAX_NEW for h in handles)
    assert eng.stats.traces == warm, \
        "admit/retire slot surgery must not retrace the compiled steps"
    assert eng.stats.compiles == warm, \
        "steady state reuses the warm-up executables (no new compiles)"


def test_serve_fault_injection_recovers_identical_streams(cfg, params):
    eng = Engine()
    with eng.activate():
        server = _ring_server(cfg, params, eng)
        for r in _requests(cfg, 3):
            server.submit(r)
        baseline = server.drain()
        crashed = _ring_server(cfg, params, eng, fail_at=(1,))
        for r in _requests(cfg, 3):
            crashed.submit(r)
        recovered = crashed.drain()
    assert crashed.recoveries == 1
    for b, r in zip(baseline, recovered):
        assert b.tokens == r.tokens, \
            f"req{b.rid}: stream changed across injected failure"


def test_straggler_hook_flags_slow_host():
    mon = StragglerMonitor()
    eng = Engine(monitor=mon)
    for _ in range(mon.cfg.patience + 3):
        eng.observe_step_time(0.1, host=0)
        eng.observe_step_time(0.1, host=1)
        eng.observe_step_time(1.0, host=2)  # 10x the median
    assert eng.swap_requests == [2]


# -------------------------------------------------- noisy key threading
def _noisy_cfg(cfg):
    spec = FabricSpec(bits_a=2, bits_w=2, mode="sim", backend="jnp",
                      noise=NoiseSpec(mismatch_sigma=0.3))
    return dataclasses.replace(cfg, fabric=spec, imc_mode="off")


def test_noisy_serve_keys_thread_through_jit(cfg, params):
    ncfg = _noisy_cfg(cfg)
    prompt = np.arange(PROMPT, dtype=np.int32)[None] % ncfg.vocab_size

    def tokens(seed):
        eng = Engine(noise_seed=seed)
        with eng.activate():
            pf = eng.prefill_step(ncfg, max_new_tokens=3)
            dec = eng.decode_step(ncfg)
            logits, cache = pf(params, {"tokens": prompt}, eng.noise_key(0))
            out = [int(np.argmax(logits[0]))]
            for t in range(1, 4):
                logits, cache = dec(params, cache,
                                    np.asarray([[out[-1]]], np.int32),
                                    eng.noise_key(t))
                out.append(int(np.argmax(logits[0])))
        return out

    assert tokens(0) == tokens(0), "same seed must give identical tokens"
    assert tokens(0) != tokens(7), \
        "different seeds must draw different noise (keys are traced, not baked)"


@pytest.mark.slow
def test_noisy_train_keys_thread_through_jit(cfg):
    ncfg = dataclasses.replace(_noisy_cfg(cfg), remat=False)
    params0 = init_params(jax.random.key(0), ncfg)
    batch = {"tokens": np.zeros((2, 8), np.int32),
             "labels": np.ones((2, 8), np.int32)}

    eng = Engine()
    with eng.activate():
        step = eng.train_step(ncfg, donate=False)

        def losses(seed):
            e = Engine(noise_seed=seed)
            out = []
            p, o = params0, init_adamw(params0)
            for s in range(2):
                p, o, m = step(p, o, batch, e.noise_key(s))
                out.append(float(m["loss"]))
            return out

        a, b, c = losses(0), losses(0), losses(7)
    assert a == b, "same seed must be bit-identical across runs"
    assert a != c, "different seeds must differ"
    assert eng.stats.compiles == 1, "both runs share one executable"
