"""Paged KV cache + Server API tests.

The headline guarantee: ragged mixed-length decode through the paged block
pool is **bit-identical** to the sequential (B=1, ring-cache) oracle, with
zero steady-state recompiles.  Around it: BlockAllocator invariants
(exhaustion -> queued admission, release/realloc reuse, dense-prefix tables),
per-request termination (``max_new_tokens`` / ``eos_id``) with early block
release, admission rejection, and fault re-queue determinism.  A hypothesis
property test (skipped when hypothesis is absent) drives random admit/grow/
finish schedules and asserts no block is ever double-assigned.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.launch.engine import Engine
from repro.launch.server import Request, Server
from repro.models.kv_cache import BlockAllocator, OutOfBlocks
from repro.models.model import decode_step, init_params, prefill

LENGTHS = (7, 16, 33, 12, 5)  # straddles the 16/48 buckets and block edges
MAX_NEW = 6


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("qwen2.5-3b"))


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.key(0), cfg)


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in lengths]


def _oracle(cfg, params, prompt, max_new=MAX_NEW):
    """Unbatched greedy reference: plain ring prefill + decode, no padding."""
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompt[None])},
                            cfg, max_new_tokens=max_new)
    out = [int(jnp.argmax(logits[0]))]
    while len(out) < max_new:
        logits, cache = decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32), cfg)
        out.append(int(jnp.argmax(logits[0])))
    return out


def _server(cfg, params, engine, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("block_size", 8)
    kw.setdefault("buckets", (16, 48))
    kw.setdefault("max_seq_len", 48 + MAX_NEW)
    return Server(cfg, params, engine=engine, **kw)


# --------------------------------------------------------------- allocator
def test_allocator_exhaustion_and_reuse():
    a = BlockAllocator(num_blocks=6, block_size=4, slots=3)
    got = a.alloc(0, 2, reserve=1)  # holds 2, promises 1 more
    assert len(got) == 2 and a.num_free == 4 and a.available == 3
    assert a.can_admit(3) and not a.can_admit(4)
    with pytest.raises(OutOfBlocks):
        a.alloc(1, 4)  # free list has 4 but one is reserved for slot 0
    a.alloc(1, 3)
    assert a.available == 0
    with pytest.raises(OutOfBlocks):
        a.alloc(2, 1)
    # append draws the reservation first — never steals unpromised blocks
    a.append(0)
    assert a.slot_blocks(0) == got + [a.slot_blocks(0)[-1]]
    with pytest.raises(OutOfBlocks):
        a.append(1)  # slot 1 reserved nothing and the pool is dry
    a.check()
    # release -> realloc reuses the same physical ids
    freed = set(a.release(0))
    again = set(a.alloc(2, 3))
    assert again <= freed
    a.check()


def test_allocator_tables_are_dense_prefixes():
    a = BlockAllocator(num_blocks=8, block_size=2, slots=2,
                       max_blocks_per_slot=4)
    a.alloc(0, 2)
    a.alloc(1, 1)
    a.append(1)
    t = a.table()
    assert t.shape == (2, 4) and t.dtype == np.int32
    for row, n in zip(t, (2, 2)):
        assert (row[:n] >= 0).all() and (row[n:] == -1).all(), \
            "block table row is not a dense prefix"
    assert (a.table_row(1) == t[1]).all()
    with pytest.raises(OutOfBlocks):
        a.alloc(0, 3)  # would exceed the per-slot table width
    a.check()


def test_allocator_random_schedule_never_double_assigns():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(st.lists(st.tuples(st.sampled_from(["alloc", "append",
                                                   "release"]),
                                  st.integers(0, 2), st.integers(0, 3),
                                  st.integers(0, 2)),
                        min_size=1, max_size=40))
    def run(schedule):
        a = BlockAllocator(num_blocks=8, block_size=4, slots=3,
                           max_blocks_per_slot=4)
        for op, slot, n, reserve in schedule:
            try:
                if op == "alloc":
                    a.alloc(slot, n, reserve=reserve)
                elif op == "append":
                    a.append(slot)
                else:
                    a.release(slot)
            except OutOfBlocks:
                pass  # admission pressure is expected; state must stay sane
            a.check()  # partition + dense prefix + reservation invariants

    run()


# ------------------------------------------------- the headline guarantee
def test_paged_ragged_decode_bit_identical_to_oracle(cfg, params):
    prompts = _prompts(cfg, LENGTHS)
    eng = Engine()
    with eng.activate():
        server = _server(cfg, params, eng)
        handles = [server.submit(Request(p, max_new_tokens=MAX_NEW))
                   for p in prompts]
        server.drain()
        warm = eng.stats.traces
        # steady state: a second mixed-length wave must be data-only
        wave2 = [server.submit(Request(p, max_new_tokens=MAX_NEW))
                 for p in reversed(prompts)]
        server.drain()
    assert all(h.done for h in handles + wave2)
    for h in handles + wave2:
        assert h.tokens == _oracle(cfg, params, h.request.prompt), (
            f"len-{len(h.request.prompt)} stream diverged from the "
            f"sequential oracle")
    assert eng.stats.traces == warm, \
        "mixed-length steady state must not retrace any compiled step"
    server.alloc.check()
    assert server.alloc.num_free == server.num_blocks, \
        "finished requests must return every block"


def test_submit_rejects_impossible_requests(cfg, params):
    eng = Engine()
    with eng.activate():
        server = _server(cfg, params, eng)
        too_long = server.submit(Request(
            _prompts(cfg, [49])[0], max_new_tokens=1))
        assert too_long.status == "rejected" and "bucket" in too_long.reason
        too_greedy = server.submit(Request(
            _prompts(cfg, [48])[0], max_new_tokens=100))
        assert too_greedy.status == "rejected"
        assert "never fit" in too_greedy.reason
        assert not server.queued, "rejected requests must not queue"


def test_block_exhaustion_queues_then_admits_on_release(cfg, params):
    prompts = _prompts(cfg, (16, 16, 16))
    eng = Engine()
    with eng.activate():
        # pool sized for ONE worst-case request (16+6 tokens -> 3 blocks)
        server = _server(cfg, params, eng, slots=2, num_blocks=3,
                         buckets=(16,), max_seq_len=16 + MAX_NEW)
        handles = [server.submit(Request(p, max_new_tokens=MAX_NEW))
                   for p in prompts]
        server.poll()
        assert sum(h.status == "active" for h in handles) == 1, \
            "block budget admits exactly one request at a time"
        assert sum(h.status == "queued" for h in handles) == 2
        server.drain()
    assert [h.tokens for h in handles] == \
        [_oracle(cfg, params, p) for p in prompts]
    server.alloc.check()


def test_per_request_termination_and_early_release(cfg, params):
    base = _prompts(cfg, (16,))[0]
    ref = _oracle(cfg, params, base, max_new=8)
    eos = ref[2]
    stop = ref.index(eos) + 1  # first occurrence wins
    eng = Engine()
    with eng.activate():
        server = _server(cfg, params, eng)
        short = server.submit(Request(base, max_new_tokens=3))
        eosed = server.submit(Request(base, max_new_tokens=8, eos_id=eos))
        server.drain()
    assert short.tokens == ref[:3], "per-request max_new_tokens budget"
    assert eosed.tokens == ref[:stop], "stream must stop AT the eos token"
    assert server.alloc.num_free == server.num_blocks, \
        "early termination must release the slot's blocks"


def test_fault_requeue_replays_identical_streams(cfg, params):
    prompts = _prompts(cfg, (7, 16, 33))
    eng = Engine()
    with eng.activate():
        baseline = _server(cfg, params, eng)
        for p in prompts:
            baseline.submit(Request(p, max_new_tokens=MAX_NEW))
        baseline.drain()
        crashed = _server(cfg, params, eng, fail_at=(1,))
        for p in prompts:
            crashed.submit(Request(p, max_new_tokens=MAX_NEW))
        crashed.drain()
    assert crashed.recoveries == 1
    for b, c in zip(baseline.handles, crashed.handles):
        assert b.tokens == c.tokens, \
            "re-queued requests must replay bit-identical greedy streams"
    crashed.alloc.check()


def test_ring_mode_is_the_same_api(cfg, params):
    """kv='ring' serves uniform traffic behind submit/poll/drain too."""
    prompts = _prompts(cfg, (16, 16, 16))
    eng = Engine()
    with eng.activate():
        server = Server(cfg, params, engine=eng, slots=2, kv="ring")
        handles = [server.submit(Request(p, max_new_tokens=MAX_NEW))
                   for p in prompts]
        ragged = server.submit(Request(_prompts(cfg, [7])[0],
                                       max_new_tokens=MAX_NEW))
        server.drain()
    assert ragged.status == "rejected" and "uniform" in ragged.reason
    for h in handles:
        assert h.tokens == _oracle(cfg, params, h.request.prompt)
