"""Hypothesis property tests on the system's invariants.

``hypothesis`` is an optional dev dependency — skip (never hard-fail
collection) when it is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import constants as C
from repro.core.bitserial import bitserial_matmul_unsigned, group_counts
from repro.core.decoder import decode_voltage
from repro.core.logic import logic_from_count
from repro.core.montecarlo import mc_energy_fj
from repro.core.quant import (dequantize, from_bitplanes, quantize,
                              signed_product_correction, to_bitplanes,
                              to_offset_binary)
from repro.core.rbl import rbl_voltage

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.integers(0, 8))
@settings(**SETTINGS)
def test_decode_roundtrip_every_count(k):
    """decode(thermometer(V(k))) == k for every k — both voltage models."""
    for mode in ("lut", "physics"):
        v = rbl_voltage(jnp.int32(k), mode=mode)
        assert int(decode_voltage(v, mode=mode)) == k


@given(st.integers(2, 8), st.integers(0, 8))
@settings(**SETTINGS)
def test_logic_consistency(m, count):
    count = min(count, m)
    out = logic_from_count(jnp.int32(count), m=m)
    assert int(out["AND"]) == int(count == m)
    assert int(out["NOR"]) == int(count == 0)
    assert int(out["XOR"]) == count % 2
    assert int(out["AND"]) + int(out["NAND"]) == 1
    assert int(out["OR"]) + int(out["NOR"]) == 1
    assert int(out["XOR"]) + int(out["XNOR"]) == 1
    assert int(out["SUM"]) == int(out["XOR"])
    assert int(out["CARRY"]) == int(out["AND"])


@given(st.lists(st.booleans(), min_size=2, max_size=2),
       st.lists(st.booleans(), min_size=2, max_size=2))
@settings(**SETTINGS)
def test_two_operand_truth_tables(a, b):
    """All four 2-bit patterns, against python ground truth (Table II)."""
    count = int(a[0] and b[0]) + int(a[1] and b[1])
    # model: rows hold a AND b per cell; count == number of matched highs
    out = logic_from_count(jnp.int32(int(a[0]) + int(a[1])), m=2)
    x, y = int(a[0]), int(a[1])
    assert int(out["AND"]) == (x & y)
    assert int(out["OR"]) == (x | y)
    assert int(out["XOR"]) == (x ^ y)
    del count


@given(st.integers(1, 6), st.integers(1, 40), st.integers(1, 12),
       st.integers(0, 2**32 - 1))
@settings(**SETTINGS)
def test_group_counts_partition_matmul(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, size=(m, k)).astype(np.uint8)
    w = rng.integers(0, 2, size=(k, n)).astype(np.uint8)
    counts = np.asarray(group_counts(jnp.asarray(a), jnp.asarray(w)))
    assert counts.max(initial=0) <= C.ROWS
    np.testing.assert_array_equal(counts.sum(axis=-2),
                                  a.astype(np.int32) @ w.astype(np.int32))


@given(st.integers(2, 8), st.integers(0, 2**32 - 1))
@settings(max_examples=10, deadline=None)
def test_bitserial_equals_matmul(bits, seed):
    rng = np.random.default_rng(seed)
    hi = 1 << bits
    ua = jnp.asarray(rng.integers(0, hi, size=(3, 11)).astype(np.int32))
    uw = jnp.asarray(rng.integers(0, hi, size=(11, 5)).astype(np.int32))
    out = bitserial_matmul_unsigned(ua, uw, bits_a=bits, bits_w=bits)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ua) @ np.asarray(uw))


@given(st.integers(0, 2**32 - 1), st.integers(2, 8))
@settings(**SETTINGS)
def test_quant_dequant_bounded(seed, bits):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(7, 9)).astype(np.float32) * 10)
    q = quantize(x, bits)
    err = jnp.abs(dequantize(q) - x)
    assert float(jnp.max(err)) <= float(jnp.max(0.5 * q.scale)) + 1e-5


@given(st.integers(0, 2**32 - 1))
@settings(**SETTINGS)
def test_offset_binary_identity(seed):
    rng = np.random.default_rng(seed)
    qa = jnp.asarray(rng.integers(-127, 128, size=(3, 8)).astype(np.int32))
    qw = jnp.asarray(rng.integers(-127, 128, size=(8, 4)).astype(np.int32))
    ua, uw = to_offset_binary(qa), to_offset_binary(qw)
    got = ua @ uw - signed_product_correction(ua, uw)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(qa) @ np.asarray(qw))


@given(st.integers(0, 255))
@settings(**SETTINGS)
def test_bitplane_roundtrip_prop(v):
    u = jnp.full((3,), v, jnp.int32)
    assert int(from_bitplanes(to_bitplanes(u))[0]) == v


@given(st.integers(0, 8))
@settings(max_examples=9, deadline=None)
def test_energy_monotone_and_mc_mean_tracks(k):
    e = np.asarray(
        jnp.stack([jnp.float32(0)] if k == 0 else
                  [mc_energy_fj(jax.random.key(1), k, 4000).mean()]))
    lut = C.E_MAC_TABLE_FJ[k]
    if k > 0:
        # MC mean stays within 10% of the (mu_g-shifted) table energy
        assert abs(float(e[0]) - (C.E_MAC_TABLE_FJ[0] + C.MC_MU_G *
                                  (lut - C.E_MAC_TABLE_FJ[0]))) < 0.1 * lut


@given(st.floats(0.0, 8.0))
@settings(**SETTINGS)
def test_voltage_monotone_in_fractional_k(k):
    v1 = float(rbl_voltage(jnp.float32(k), mode="physics"))
    v2 = float(rbl_voltage(jnp.float32(k + 0.25), mode="physics"))
    assert v2 < v1
