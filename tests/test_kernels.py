"""Kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracles.

Shape/dtype sweeps per the kernel contract; allclose against ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decoder import thresholds as core_thresholds
from repro.kernels.imc_mac.ops import imc_mac, imc_mac_dequant
from repro.kernels.imc_mac.ref import imc_mac_dequant_ref, imc_mac_ref
from repro.kernels.rbl_decode.ops import rbl_decode_mac
from repro.kernels.rbl_decode.ref import rbl_decode_mac_ref

SHAPES = [
    (8, 16, 8),        # tiny, fully padded
    (128, 128, 128),   # exactly one block
    (256, 384, 128),   # multi-block M/K
    (100, 130, 50),    # ragged everything
    (1, 8, 1),         # degenerate
]


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_imc_mac_matches_ref(m, k, n):
    rng = np.random.default_rng(hash((m, k, n)) % 2**32)
    qa = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    qw = jnp.asarray(rng.integers(-127, 128, size=(k, n)), jnp.int8)
    out = imc_mac(qa, qw, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(imc_mac_ref(qa, qw)))


@pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (256, 128, 256),
                                      (128, 256, 512)])
def test_imc_mac_block_shape_sweep(bm, bn, bk):
    rng = np.random.default_rng(0)
    qa = jnp.asarray(rng.integers(-127, 128, size=(200, 300)), jnp.int8)
    qw = jnp.asarray(rng.integers(-127, 128, size=(300, 170)), jnp.int8)
    out = imc_mac(qa, qw, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(imc_mac_ref(qa, qw)))


def test_imc_mac_batch_dims():
    rng = np.random.default_rng(1)
    qa = jnp.asarray(rng.integers(-127, 128, size=(4, 6, 96)), jnp.int8)
    qw = jnp.asarray(rng.integers(-127, 128, size=(96, 32)), jnp.int8)
    out = imc_mac(qa, qw, interpret=True)
    assert out.shape == (4, 6, 32)
    ref = imc_mac_ref(qa.reshape(24, 96), qw).reshape(4, 6, 32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_imc_mac_int32_accumulation_no_overflow():
    # Worst case magnitudes over a deep K: |acc| = 127*127*2048 ~ 3.3e7 < 2^31.
    qa = jnp.full((8, 2048), 127, jnp.int8)
    qw = jnp.full((2048, 8), -127, jnp.int8)
    out = imc_mac(qa, qw, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.full((8, 8), -127 * 127 * 2048))


@pytest.mark.parametrize("m,k,n", [(64, 96, 32), (130, 140, 150)])
def test_imc_mac_dequant_matches_ref(m, k, n):
    rng = np.random.default_rng(2)
    qa = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    qw = jnp.asarray(rng.integers(-127, 128, size=(k, n)), jnp.int8)
    sa = jnp.float32(0.0123)
    sw = jnp.asarray(rng.uniform(0.001, 0.1, size=(n,)), jnp.float32)
    out = imc_mac_dequant(qa, qw, sa, sw, interpret=True)
    ref = imc_mac_dequant_ref(qa, qw, sa, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("m,k,n", [(16, 64, 8), (128, 256, 128), (50, 70, 30)])
def test_rbl_decode_matches_ref(m, k, n):
    rng = np.random.default_rng(hash((m, k, n, 1)) % 2**32)
    a = jnp.asarray(rng.integers(0, 2, size=(m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 2, size=(k, n)), jnp.int8)
    out = rbl_decode_mac(a, w, interpret=True)
    ref = rbl_decode_mac_ref(a, w, mode="physics")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_rbl_decode_equals_plain_popcount_matmul():
    # Noise-free decode is exact -> grouped path == plain binary matmul.
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.integers(0, 2, size=(32, 120)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 2, size=(120, 16)), jnp.int8)
    out = rbl_decode_mac(a, w, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(a, np.int32) @ np.asarray(w, np.int32))


def test_rbl_decode_custom_thresholds_detune():
    # Detuned comparator references (paper §IV-C corner re-tuning): shifting
    # all thresholds up by a full level makes every group read one count high
    # (where headroom exists) — decode errors must materialize.
    rng = np.random.default_rng(6)
    a = jnp.ones((16, 64), jnp.int8)
    w = jnp.ones((64, 8), jnp.int8)
    good = core_thresholds(8, mode="physics")
    out_good = rbl_decode_mac(a, w, good, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_good), np.full((16, 8), 64))
    detuned = jnp.concatenate([jnp.array([1.9]), good[:-1]])  # shift one level
    out_bad = rbl_decode_mac(a, w, detuned, interpret=True)
    assert np.all(np.asarray(out_bad) != np.asarray(out_good))


def test_rbl_decode_rows_16_physics():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.integers(0, 2, size=(24, 160)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 2, size=(160, 8)), jnp.int8)
    out = rbl_decode_mac(a, w, rows=16, bk=256, interpret=True)
    ref = rbl_decode_mac_ref(a, w, rows=16, mode="physics")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
