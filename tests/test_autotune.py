"""Autotuner tests: cache round-trip, trial-free warm runs, pins, and the
geometry token riding the Engine step-cache key."""
import json

import pytest

from repro.configs import get_config, reduce_config
from repro.kernels import autotune
from repro.kernels.autotune.tuner import DEFAULTS
from repro.launch.engine import Engine
from repro.telemetry import get_registry

TINY_SHAPES = {"m": 8, "k": 16, "n": 8, "ba": 2, "bw": 2}
TINY_SPACE = [{"bm": 8, "bn": 8, "bk": 8}, {"bm": 8, "bn": 8, "bk": 16}]


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("qwen2.5-3b"))


@pytest.fixture(autouse=True)
def _restore_default_cache():
    yield
    autotune.set_cache(None)  # re-resolve the committed cache afterwards


def _trials():
    return get_registry().counter("autotune.trials").value


# ------------------------------------------------------------ buckets/keys
def test_shape_bucket_rounds_up_to_pow2():
    assert autotune.shape_bucket({"m": 100, "k": 512, "n": 1}) == \
        "k512_m128_n1"
    # nearby shapes share a bucket; order of dict keys is irrelevant
    assert autotune.shape_bucket({"k": 400, "m": 65}) == \
        autotune.shape_bucket({"m": 128, "k": 300})


def test_backend_key_marks_interpret():
    assert autotune.backend_key(True).endswith("+interpret")
    assert not autotune.backend_key(False).endswith("+interpret")


# ------------------------------------------------------- cold/warm tuning
def test_cold_tune_then_warm_is_trial_free(tmp_path):
    cache = autotune.AutotuneCache(path=str(tmp_path / "tuned.json"))
    before = _trials()
    geom = autotune.tune("bitplane_mac", TINY_SHAPES, TINY_SPACE,
                         repeats=1, warmup=0, cache=cache)
    cold_trials = _trials() - before
    assert cold_trials == len(TINY_SPACE)
    assert geom in [{**DEFAULTS["bitplane_mac"], **c} for c in TINY_SPACE]
    # the winner landed on disk with its timing
    rec = json.loads((tmp_path / "tuned.json").read_text())
    (entry,) = rec["entries"].values()
    assert entry["geometry"] == geom and entry["us"] > 0
    # warm: same cell resolves from the cache with ZERO further trials
    before = _trials()
    assert autotune.tune("bitplane_mac", TINY_SHAPES, TINY_SPACE,
                         cache=cache) == geom
    assert _trials() == before
    # and a fresh cache object round-trips the same file
    reloaded = autotune.AutotuneCache(path=str(tmp_path / "tuned.json"))
    before = _trials()
    assert autotune.tune("bitplane_mac", TINY_SHAPES, TINY_SPACE,
                         cache=reloaded) == geom
    assert _trials() == before


def test_committed_cache_covers_standard_cells_trial_free():
    """The CI guarantee: the repo's tuned.json answers every cell
    ``tune_standard`` would tune, so CI never runs a trial."""
    before = _trials()
    rows = autotune.tune_standard(smoke=True)
    assert _trials() == before
    assert {r[0] for r in rows} == {"bitplane_mac", "paged_attn"}


# ------------------------------------------------------- lookup precedence
def test_lookup_defaults_cache_pin_precedence(tmp_path, monkeypatch):
    cache = autotune.AutotuneCache(path=str(tmp_path / "t.json"))
    shapes = {"m": 8, "k": 16, "n": 8}
    # nothing known: hardcoded defaults
    assert autotune.lookup("bitplane_mac", shapes, cache=cache) == \
        DEFAULTS["bitplane_mac"]
    # cached winner overrides defaults
    cache.store("bitplane_mac", autotune.shape_bucket(shapes), "int8",
                autotune.backend_key(False), {"bm": 8, "bn": 8, "bk": 16},
                1.0, 2)
    assert autotune.lookup("bitplane_mac", shapes, cache=cache,
                           interpret=False) == \
        {"bm": 8, "bn": 8, "bk": 16}
    # env pin overrides everything (partial pins merge)
    monkeypatch.setenv("REPRO_TUNE_BITPLANE_MAC", "bm=32")
    got = autotune.lookup("bitplane_mac", shapes, cache=cache,
                          interpret=False)
    assert got == {"bm": 32, "bn": 8, "bk": 16}


def test_malformed_pin_raises(monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_BITPLANE_MAC", "bm=big")
    with pytest.raises(ValueError, match="REPRO_TUNE_BITPLANE_MAC"):
        autotune.env_pins()


# --------------------------------------------------------- geometry token
def test_geometry_token_tracks_stores_and_pins(tmp_path, monkeypatch):
    t0 = autotune.geometry_token()
    assert autotune.geometry_token() == t0  # stable while nothing changes
    cache = autotune.AutotuneCache(path=str(tmp_path / "t.json"))
    cache.store("bitplane_mac", "m8", "int8", "cpu", {"bm": 8}, 1.0, 1)
    t1 = autotune.geometry_token()
    assert t1 != t0
    monkeypatch.setenv("REPRO_TUNE_PAGED_ATTN", "bps=4")
    t2 = autotune.geometry_token()
    assert t2 != t1 and ("paged_attn", (("bps", 4),)) in t2[1]


def test_geometry_token_busts_engine_step_cache(tmp_path):
    eng = Engine()
    cfg = reduce_config(get_config("qwen2.5-3b"))
    d1 = eng.decode_step(cfg)
    # steady state: repeated requests reuse the executable (zero retraces)
    assert eng.decode_step(cfg) is d1
    assert eng.stats.compiles == 1 and eng.stats.hits == 1
    # a re-tune anywhere moves the token -> the step must rebuild
    cache = autotune.AutotuneCache(path=str(tmp_path / "t.json"))
    cache.store("paged_attn", "b4", "int8", "cpu+interpret", {"bps": 2},
                1.0, 1)
    d2 = eng.decode_step(cfg)
    assert d2 is not d1 and eng.stats.compiles == 2
    # and is stable again afterwards
    assert eng.decode_step(cfg) is d2


# ------------------------------------------------------------ kernel wiring
def test_paged_attention_honors_blocks_per_step_pin(monkeypatch):
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.paged_attn.ops import paged_attention

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 1, 2, 64)).astype(np.float32))
    pools = rng.normal(size=(2, 8, 16, 2, 64)).astype(np.float32)
    kp, vp = jnp.asarray(pools[0]), jnp.asarray(pools[1])
    tbl = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    pos = jnp.asarray([63, 63], jnp.int32)
    ref = paged_attention(q, kp, vp, tbl, pos, impl="jnp")
    monkeypatch.setenv("REPRO_TUNE_PAGED_ATTN", "bps=3")
    out = paged_attention(q, kp, vp, tbl, pos, impl="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
