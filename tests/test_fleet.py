"""Fleet subsystem tests: virtual-fleet coordinator, merged telemetry,
fleet serving vs the single-host oracle, and straggler shrink + resume.

The device-hungry tests run on a LocalCoordinator virtual fleet of 2 hosts x
4 CPU devices and skip when the process has fewer than 2 devices; the
slow-marked subprocess smoke re-runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the full tier-1
suite exercises the fleet even on a 1-device box (CI's fleet-smoke tier sets
the flag directly).  The elastic-planner and telemetry-merge tests are pure
host-side logic and always run.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro
from repro.fleet import (FleetEngine, FleetServer, LocalCoordinator,
                         fleet_slos, merge_tagged, tagged_snapshot)
from repro.launch.mesh import make_submesh, partition_devices
from repro.runtime.elastic import (plan_for_fleet, plan_mesh,
                                   shrink_after_failure)
from repro.telemetry import Registry, get_registry

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (CI fleet-smoke forces 8 via XLA_FLAGS; the "
           "slow subprocess smoke below covers 1-device runs)")

LENGTHS = (7, 16, 33, 12, 5)  # the ragged schedule the paged-KV tests pin
MAX_NEW = 6


@pytest.fixture(scope="module")
def cfg():
    from repro.configs import get_config, reduce_config

    return reduce_config(get_config("qwen2.5-3b"))


@pytest.fixture(scope="module")
def params(cfg):
    from repro.models.model import init_params

    return init_params(jax.random.key(0), cfg)


# ------------------------------------------------------------ elastic plans
def test_plan_mesh_pod_axis_threshold_boundary():
    """The pod axis splits off at EXACTLY the multi-pod threshold (512)."""
    below = plan_mesh(256, model_parallel=2, base_batch=256)
    assert below.axes == ("data", "model") and below.shape == (128, 2)
    at = plan_mesh(512, model_parallel=2, base_batch=512)
    assert at.axes == ("pod", "data", "model") and at.shape == (2, 128, 2)
    assert at.n_devices == 512


def test_plan_mesh_odd_dp_stays_flat_above_threshold():
    """dp must be even to split a pod axis of 2; odd dp stays 2D even when
    the device count crosses the threshold."""
    plan = plan_mesh(512, model_parallel=512, base_batch=8)
    assert plan.axes == ("data", "model") and plan.shape == (1, 512)
    assert plan.global_batch == 8  # dp=1: per-replica IS the base batch


def test_shrink_preserves_per_replica_batch():
    plan = plan_mesh(16, model_parallel=2, base_batch=64)
    assert plan.shape == (8, 2) and plan.global_batch == 64  # 8 per replica
    shrunk = shrink_after_failure(plan, 4, model_parallel=2)
    assert shrunk.shape == (6, 2) and shrunk.n_devices == 12
    assert shrunk.global_batch == 48  # 6 replicas x the SAME 8 per replica
    assert shrunk.global_batch // 6 == plan.global_batch // 8


def test_plan_mesh_rejects_too_few_devices_for_tp():
    with pytest.raises(ValueError, match="TP"):
        plan_mesh(1, model_parallel=2, base_batch=8)


def test_plan_for_fleet_is_whole_host_sugar():
    assert plan_for_fleet(2, 4, model_parallel=2, base_batch=8) == \
        plan_mesh(8, model_parallel=2, base_batch=8)


# ------------------------------------------------------------- coordinator
def test_partition_devices_is_contiguous_and_checks_divisibility():
    fake = [f"d{i}" for i in range(8)]
    groups = partition_devices(2, devices=fake)
    assert groups == [tuple(fake[:4]), tuple(fake[4:])]
    with pytest.raises(ValueError):
        partition_devices(3, devices=fake)
    with pytest.raises(ValueError):
        partition_devices(0, devices=fake)


@multi_device
def test_local_coordinator_partitions_disjoint_submeshes():
    n = 2
    coord = LocalCoordinator(n)
    hosts = coord.hosts()
    assert [h.index for h in hosts] == list(range(n))
    seen = set()
    for h in hosts:
        assert h.n_devices == len(jax.devices()) // n
        assert set(h.devices).isdisjoint(seen)
        seen |= set(h.devices)
        assert tuple(h.mesh.axis_names) == ("data", "model")
        assert h.mesh.size == h.n_devices
    assert coord.is_controller() and coord.controller == 0
    coord.barrier("test")  # no-op, must not raise
    assert coord.all_gather({0: "x"}) == {0: "x"}


def test_make_submesh_falls_back_to_pure_dp_when_tp_does_not_divide():
    devs = jax.devices()[:1]
    mesh = make_submesh(devs, model_parallel=2)
    assert dict(mesh.shape) == {"data": 1, "model": 1}


# -------------------------------------------------------- telemetry merge
def test_merged_fleet_percentiles_match_single_registry():
    """Acceptance (b): percentiles off the merged per-host registries equal
    a single registry fed the same samples — exact, not averaged."""
    rng = np.random.default_rng(3)
    samples = rng.uniform(5e-4, 2.0, size=200)
    per_host = {0: Registry(), 1: Registry()}
    ref = Registry()
    for i, v in enumerate(samples):
        per_host[i % 2].histogram("server.tpot_s").observe(float(v))
        per_host[i % 2].counter("server.admitted").inc()
        ref.histogram("server.tpot_s").observe(float(v))
        ref.counter("server.admitted").inc()
    merged, by_host = merge_tagged(
        [tagged_snapshot(reg, h) for h, reg in per_host.items()])
    assert sorted(by_host) == [0, 1]  # per-host drill-down survives
    m = merged.snapshot()["histograms"]["server.tpot_s"]
    r = ref.snapshot()["histograms"]["server.tpot_s"]
    for q in ("p50", "p95", "p99"):
        assert m[q] == r[q], f"{q}: fleet {m[q]} != as-if-one {r[q]}"
    assert merged.snapshot()["counters"]["server.admitted"] == 200
    slos = fleet_slos(per_host)
    assert slos["n_hosts"] == 2
    assert slos["tpot_ms"] == round(r["p50"] * 1e3, 3)


# ----------------------------------------------- fleet serving vs oracle
@multi_device
def test_fleet_serve_is_bit_identical_to_single_host_oracle(cfg, params):
    """Acceptance (a): mixed-length decode through a 2-host virtual fleet
    produces bit-identical token streams to one Server fed the same
    requests, and steady-state waves stay trace-free on every host."""
    from repro.launch.engine import Engine
    from repro.launch.server import Request, Server

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in LENGTHS]
    kw = dict(slots=3, kv="paged", block_size=8, buckets=(16, 48),
              max_seq_len=48 + MAX_NEW)

    coord = LocalCoordinator(2)
    fleet = FleetEngine(coord, noise_seed=0)
    fsrv = FleetServer(cfg, params, fleet, **kw)
    fleet_handles = [fsrv.submit(Request(p, max_new_tokens=MAX_NEW))
                     for p in prompts]
    fsrv.drain()
    assert {h.host for h in fleet_handles} == {0, 1}, \
        "round-robin must actually use both hosts"

    # an odd wave size over 2 hosts alternates which host gets which
    # buckets, so warmup takes n_hosts waves; wave 3 must retrace nowhere
    wave2 = [fsrv.submit(Request(p, max_new_tokens=MAX_NEW))
             for p in prompts]
    fsrv.drain()
    warm = dict(fleet.traces_by_host())
    wave3 = [fsrv.submit(Request(p, max_new_tokens=MAX_NEW))
             for p in prompts]
    fsrv.drain()
    assert fleet.traces_by_host() == warm, \
        f"steady-state retrace: {warm} -> {fleet.traces_by_host()}"

    # oracle: ONE Server on a mesh of host 0's shape, same noise seed
    oracle = Engine(mesh=coord.hosts()[0].mesh, noise_seed=0,
                    registry=Registry())
    with oracle.activate():
        osrv = Server(cfg, params, engine=oracle, **kw)
        oracle_handles = [osrv.submit(Request(p, max_new_tokens=MAX_NEW))
                          for p in prompts]
        osrv.drain()

    for wave in (fleet_handles, wave2, wave3):
        for fh, oh in zip(wave, oracle_handles):
            assert fh.tokens == oh.tokens, \
                f"req{oh.rid}: fleet {fh.tokens} != oracle {oh.tokens}"

    # merged SLOs read as-if-one-registry over BOTH hosts' traffic
    slos = fsrv.slos()
    assert slos["n_hosts"] == 2
    assert slos["ttft_ms"] > 0 and slos["tpot_ms"] > 0
    merged = fleet.merged_registry().snapshot()
    assert merged["counters"]["server.admitted"] == 3 * len(prompts)
    assert merged["histograms"]["server.ttft_s"]["count"] == 3 * len(prompts)


# --------------------------------------- straggler -> shrink -> resume
@multi_device
def test_fleet_straggler_shrinks_plan_and_resumes_from_checkpoint(tmp_path):
    """Acceptance (c): an injected slow host is flagged from REAL per-host
    times, the plan shrinks in whole-host units with per-replica batch
    preserved, and the loop resumes from the latest checkpoint with no
    further retraces on the survivors."""
    from repro.configs import get_config, reduce_config
    from repro.launch.train import train_fleet

    tcfg = reduce_config(get_config("imc-paper-110m"))
    resumes0 = get_registry().snapshot()["counters"].get("fault.resumes", 0)
    (params, _), hist, fleet, loop = train_fleet(
        tcfg, n_hosts=2, steps=8, global_batch=4, seq_len=32,
        ckpt_root=str(tmp_path), ckpt_every=2, seed=0,
        # host 1 turns into a straggler from step 3 on (observed-time skew
        # only: no real sleeping)
        delay=lambda h, s: 5.0 if (h == 1 and s >= 3) else 0.0)

    # flagged from per-host entries -> removed from fleet AND monitor
    assert fleet.removed == [1] and fleet.active_hosts() == [0]
    assert 1 not in fleet.monitor.hosts
    assert get_registry().gauge("straggler.ewma_s.host1").value == 0.0

    # the shrink re-planned in whole-host device units, per-replica batch
    # preserved (at 8 devices: dp=4 @ 1/replica -> 4 devices, dp=2)
    assert len(loop.shrinks) == 1
    shrunk, per_host = loop.shrinks[0], fleet.host(0).n_devices
    assert shrunk is loop.plan and shrunk.n_devices == per_host
    mp = 2 if per_host % 2 == 0 else 1
    orig = plan_for_fleet(2, per_host, model_parallel=mp, base_batch=4)
    assert shrunk == shrink_after_failure(orig, per_host, model_parallel=mp)
    assert orig.global_batch // (orig.n_devices // mp) == \
        shrunk.global_batch // (shrunk.n_devices // mp), \
        "per-replica batch must survive the shrink"

    # resumed from the latest committed checkpoint, replaying some steps
    resumes = get_registry().snapshot()["counters"]["fault.resumes"]
    assert resumes == resumes0 + 1
    assert len(hist) > 8, "resume must replay post-checkpoint steps"

    # survivor replays from its compiled-step cache: warmup traces only
    # (one numpy-input trace + one committed-replica trace), none added by
    # the resume
    assert fleet.traces_by_host()[0] == 2

    assert all(np.all(np.isfinite(np.asarray(x)))
               for x in jax.tree.leaves(params))


@multi_device
def test_fleet_engine_observe_step_times_feeds_monitor_once():
    """record_step must see the FULL per-host dict once per step — per-host
    calls would multiply the strike cadence by the fleet size."""
    from repro.runtime.straggler import StragglerConfig

    fleet = FleetEngine(LocalCoordinator(2),
                        straggler_cfg=StragglerConfig(patience=3))
    for _ in range(3):
        flagged = fleet.observe_step_times({0: 0.1, 1: 0.9})
    assert flagged == [1]
    assert fleet.monitor.hosts[1].strikes == 3, \
        "strikes must advance once per fleet step, not once per host"


# ------------------------------------------------------- subprocess smoke
@pytest.mark.slow
def test_fleet_suite_under_forced_device_count():
    """1-device boxes still exercise the virtual fleet: re-run this file in
    a subprocess with 8 forced CPU devices (2 hosts x 4 devices)."""
    if os.environ.get("FLEET_SUBPROCESS") == "1":
        pytest.skip("already inside the forced-device subprocess")
    if len(jax.devices()) >= 2:
        pytest.skip("devices already forced; fleet tests ran in-process")
    src = os.path.dirname(list(repro.__path__)[0])  # namespace pkg: no __file__
    env = dict(
        os.environ, FLEET_SUBPROCESS="1", JAX_PLATFORMS="cpu",
        PYTHONPATH=os.pathsep.join(
            p for p in (src, os.environ.get("PYTHONPATH")) if p),
        XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                   + " --xla_force_host_platform_device_count=8").strip())
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        f"fleet subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    assert "passed" in proc.stdout, proc.stdout
