"""FabricSpec / Fabric facade: the typed entry point to the IMC stack.

Covers spec validation + hashability, backend-registry dispatch (with early
raises on unsupported combos), the four facade verbs (matmul/linear/logic/
cost), NoiseSpec end-to-end through a model forward, PRNG key threading down
to the bit-serial engine, asymmetric precision parity, the removed pre-spec
kwargs (legacy spellings now raise ``TypeError``), and jit-cache stability of
equal specs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.constants as C
from repro.core.bitserial import bitserial_matmul_unsigned
from repro.core.fabric import (Fabric, FabricSpec, NoiseSpec, fabric_matmul,
                               resolve_engine)
from repro.core.imc_linear import apply_imc_linear, imc_linear_apply, init_imc_linear
from repro.core.imc_matmul import imc_matmul
from repro.core.quant import quantize, signed_product_correction, to_offset_binary


def _xw(m=8, k=64, n=16, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)))


# ------------------------------------------------------------------- spec
def test_spec_validation_raises():
    with pytest.raises(ValueError, match="mode"):
        FabricSpec(mode="approximate")
    with pytest.raises(ValueError, match="backend"):
        FabricSpec(backend="cuda")
    with pytest.raises(ValueError, match="bits_a"):
        FabricSpec(bits_a=9)
    with pytest.raises(ValueError, match="bits_w"):
        FabricSpec(bits_w=1)
    # noise is a sim-path concept
    with pytest.raises(ValueError, match="sim"):
        FabricSpec(mode="exact", noise=NoiseSpec.calibrated())
    # noisy + pallas is a supported engine since the in-kernel PRNG landed
    assert FabricSpec(mode="sim", backend="pallas",
                      noise=NoiseSpec.calibrated()).label == "sim/pallas+noise"
    with pytest.raises(ValueError, match=">= 0"):
        NoiseSpec(mismatch_sigma=-0.1)


def test_spec_hashable_and_noise_canonicalized():
    a = FabricSpec(mode="sim", backend="jnp")
    b = FabricSpec(mode="sim", backend="jnp", noise=NoiseSpec())
    assert a == b and hash(a) == hash(b)  # all-off NoiseSpec -> None
    assert b.noise is None and not b.noisy
    n = FabricSpec(mode="sim", noise=NoiseSpec(mismatch_sigma=0.05))
    assert n.noisy and n != a
    assert len({a, b, n}) == 2  # usable as a dict/jit-cache key


def test_spec_labels_and_bits_accessor():
    assert FabricSpec(backend="jnp").label == "exact/jnp"
    assert FabricSpec(mode="sim", backend="pallas").label == "sim/pallas"
    assert FabricSpec(mode="sim", backend="jnp",
                      noise=NoiseSpec.calibrated()).label == "sim/jnp+noise"
    assert FabricSpec().bits == 8
    with pytest.raises(ValueError, match="asymmetric"):
        FabricSpec(bits_a=4, bits_w=8).bits


def test_resolve_engine_covers_all_valid_combos():
    for spec in (FabricSpec(backend="jnp"), FabricSpec(backend="pallas"),
                 FabricSpec(mode="sim", backend="jnp"),
                 FabricSpec(mode="sim", backend="pallas"),
                 FabricSpec(mode="sim", backend="jnp",
                            noise=NoiseSpec.calibrated()),
                 FabricSpec(mode="sim", backend="pallas",
                            noise=NoiseSpec.calibrated())):
        assert callable(resolve_engine(spec))
        assert callable(Fabric(spec)._engine)


# ----------------------------------------------------------------- matmul
def test_fabric_matmul_exact_and_sim_agree():
    x, w = _xw()
    ye = fabric_matmul(x, w, FabricSpec(backend="jnp"))
    ys = fabric_matmul(x, w, FabricSpec(mode="sim", backend="jnp"))
    np.testing.assert_allclose(np.asarray(ye), np.asarray(ys), rtol=1e-6)
    ref = np.asarray(x @ w)
    rel = np.linalg.norm(np.asarray(ye) - ref) / np.linalg.norm(ref)
    assert rel < 0.02


def test_fabric_matmul_noisy_requires_key():
    x, w = _xw()
    spec = FabricSpec(mode="sim", noise=NoiseSpec.calibrated())
    with pytest.raises(ValueError, match="key"):
        Fabric(spec).matmul(x, w)


def test_fabric_matmul_noisy_differs_but_bounded():
    x, w = _xw(seed=3)
    fab = Fabric(FabricSpec(mode="sim", backend="jnp",
                            noise=NoiseSpec(mismatch_sigma=0.1,
                                            comparator_offset_sigma=0.005)))
    y0 = fabric_matmul(x, w, FabricSpec(mode="sim", backend="jnp"))
    yn = fab.matmul(x, w, key=jax.random.key(0))
    ref = np.asarray(x @ w)
    assert not np.array_equal(np.asarray(yn), np.asarray(y0))
    rel = np.linalg.norm(np.asarray(yn) - ref) / np.linalg.norm(ref)
    assert rel < 0.25  # noisy, but decode margins keep it in the ballpark


def test_key_threads_down_to_bitserial_engine():
    # The facade must hand the caller's key to bitserial_matmul_unsigned
    # unchanged: reproduce its output by hand with the same key.
    x, w = _xw(seed=4)
    sigma = 0.4
    spec = FabricSpec(mode="sim", backend="jnp",
                      noise=NoiseSpec(mismatch_sigma=sigma))
    key = jax.random.key(11)
    y = fabric_matmul(x, w, spec, key=key)

    qx = quantize(x, 8, axis=None)
    qw = quantize(w, 8, axis=0)
    ua, uw = to_offset_binary(qx.q, 8), to_offset_binary(qw.q, 8)
    uu = bitserial_matmul_unsigned(ua, uw, bits_a=8, bits_w=8, mode="sim",
                                   key=key, mismatch_sigma=sigma)
    acc = uu - signed_product_correction(ua, uw, 8)
    ref = acc.astype(jnp.float32) * qx.scale * qw.scale.reshape(1, -1)
    # identical noise draws; only jit-vs-eager dequant fusion rounding differs
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


# --------------------------------------------------- asymmetric precision
def test_asymmetric_correction_identity():
    rng = np.random.default_rng(5)
    qa = rng.integers(-7, 8, size=(6, 24)).astype(np.int32)  # 4-bit
    qw = rng.integers(-127, 128, size=(24, 10)).astype(np.int32)  # 8-bit
    ua = to_offset_binary(jnp.asarray(qa), 4)
    uw = to_offset_binary(jnp.asarray(qw), 8)
    corr = signed_product_correction(ua, uw, 4, 8)
    np.testing.assert_array_equal(np.asarray(ua @ uw - corr), qa @ qw)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_asymmetric_4x8_sim_parity_vs_float(backend):
    x, w = _xw(m=4, k=48, n=8, seed=6)
    spec = FabricSpec(bits_a=4, bits_w=8, mode="sim", backend=backend)
    y = fabric_matmul(x, w, spec)
    # bit-exact vs the exact digital-equivalent at the same precisions
    ye = fabric_matmul(x, w, FabricSpec(bits_a=4, bits_w=8, backend="jnp"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), rtol=1e-6)
    # and within the 4-bit activation quantization budget of the float ref
    ref = np.asarray(x @ w)
    rel = np.linalg.norm(np.asarray(y) - ref) / np.linalg.norm(ref)
    assert rel < 0.2


# ----------------------------------------------------------------- linear
def test_fabric_linear_forward_and_ste_grads():
    fab = Fabric(FabricSpec(mode="sim", backend="jnp"))
    p = init_imc_linear(jax.random.key(0), 32, 16, use_bias=True)
    x = jax.random.normal(jax.random.key(1), (8, 32))

    def loss(params, x):
        y = fab.linear(params, x)
        return jnp.sum(y * y)

    val, grads = jax.value_and_grad(loss)(p, x)
    assert np.isfinite(float(val))
    assert grads["w"].shape == (32, 16) and grads["b"].shape == (16,)
    y = fab.linear(p, x)
    np.testing.assert_allclose(np.asarray(grads["b"]),
                               np.asarray(2 * y.sum(0)), rtol=1e-4)


def test_fabric_linear_noisy_keyed_deterministic():
    fab = Fabric(FabricSpec(mode="sim", backend="jnp",
                            noise=NoiseSpec(mismatch_sigma=0.3)))
    p = init_imc_linear(jax.random.key(0), 24, 8)
    x = jax.random.normal(jax.random.key(1), (4, 24))
    y1 = fab.linear(p, x, key=jax.random.key(2))
    y2 = fab.linear(p, x, key=jax.random.key(2))
    y3 = fab.linear(p, x, key=jax.random.key(3))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert not np.array_equal(np.asarray(y1), np.asarray(y3))


# ------------------------------------------------------------------ logic
def test_fabric_logic_matches_boolean_ops():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.integers(0, 2, size=64).astype(np.uint8))
    b = jnp.asarray(rng.integers(0, 2, size=64).astype(np.uint8))
    an, bn = np.asarray(a), np.asarray(b)
    truth = {"AND": an & bn, "OR": an | bn, "XOR": an ^ bn,
             "NAND": 1 - (an & bn), "NOR": 1 - (an | bn),
             "XNOR": 1 - (an ^ bn), "SUM": an ^ bn, "CARRY": an & bn}
    for spec in (FabricSpec(), FabricSpec(mode="sim")):
        fab = Fabric(spec)
        for op, want in truth.items():
            np.testing.assert_array_equal(np.asarray(fab.logic(a, b, op)),
                                          want, err_msg=f"{spec.label}:{op}")
    with pytest.raises(ValueError, match="op"):
        Fabric(FabricSpec()).logic(a, b, "MAJ")


def test_fabric_logic_noisy_keyed():
    a = jnp.ones((4096,), jnp.uint8)
    b = jnp.ones((4096,), jnp.uint8)
    fab = Fabric(FabricSpec(mode="sim", backend="jnp",
                            noise=NoiseSpec(mismatch_sigma=0.5)))
    with pytest.raises(ValueError, match="key"):
        fab.logic(a, b, "AND")
    out = fab.logic(a, b, "AND", key=jax.random.key(0))
    flips = int(np.sum(np.asarray(out) != 1))
    assert 0 < flips < 4096  # noise visibly flips some decodes, not all


# ------------------------------------------------------------------- cost
def test_fabric_cost_tracks_spec_precision():
    rep88 = Fabric(FabricSpec()).cost((128, 256), (256, 64))
    rep48 = Fabric(FabricSpec(bits_a=4, bits_w=8)).cost((128, 256), (256, 64))
    assert rep88.evaluations == 128 * 32 * 64 * 8
    assert rep48.evaluations == rep88.evaluations // 2  # half the a-planes
    assert rep48.energy_j < rep88.energy_j


# ------------------------------------------- NoiseSpec through a model
def test_noisy_spec_end_to_end_through_model_forward():
    from repro.configs import get_config, reduce_config
    from repro.models.common import fabric_noise_key
    from repro.models.model import forward_logits, init_params

    cfg = reduce_config(get_config("qwen2.5-3b"))
    cfg_exact = dataclasses.replace(cfg, fabric=FabricSpec(backend="jnp"))
    cfg_noisy = dataclasses.replace(cfg, fabric=FabricSpec(
        mode="sim", backend="jnp", noise=NoiseSpec.calibrated()))
    params = init_params(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0,
                                          cfg.vocab_size)}
    exact = forward_logits(params, batch, cfg_exact)
    with pytest.raises(ValueError, match="key"):
        forward_logits(params, batch, cfg_noisy)  # noisy needs a key source
    with fabric_noise_key(jax.random.key(7)):
        noisy = forward_logits(params, batch, cfg_noisy)
    assert not np.array_equal(np.asarray(noisy), np.asarray(exact))
    rel = (np.linalg.norm(np.asarray(noisy - exact))
           / np.linalg.norm(np.asarray(exact)))
    assert rel < 0.2  # calibrated mismatch: rare decode flips, model intact


def test_config_parses_legacy_imc_fields_into_fabric():
    from repro.configs import get_config, reduce_config

    cfg = reduce_config(get_config("qwen2.5-3b"))
    assert cfg.imc_fabric is None  # imc off
    legacy = dataclasses.replace(cfg, imc_mode="sim", imc_bits=4)
    assert legacy.imc_fabric == FabricSpec(bits_a=4, bits_w=4, mode="sim")
    # the typed channel wins when set; the legacy fields are left untouched
    spec = FabricSpec(bits_a=4, bits_w=8, mode="sim")
    typed = dataclasses.replace(cfg, fabric=spec)
    assert typed.imc_fabric == spec and typed.imc_mode == "off"
    assert hash(typed) != hash(cfg)  # configs stay hashable with a spec


def test_config_fabric_channels_behave_under_replace():
    from repro.configs import get_config, reduce_config

    base = reduce_config(get_config("qwen2.5-3b"))
    spec = FabricSpec(bits_a=4, bits_w=8, mode="sim", backend="jnp")
    cfg = dataclasses.replace(base, fabric=spec)
    # a conflicting legacy write on a fabric-carrying config raises loudly
    # instead of being silently ignored or silently rebuilding a lesser spec
    with pytest.raises(ValueError, match="authoritative"):
        dataclasses.replace(cfg, imc_mode="exact")
    # fabric=None alone turns IMC off — no resurrection from stale fields
    off = dataclasses.replace(cfg, fabric=None)
    assert off.imc_fabric is None and off.fabric is None
    # legacy-built configs keep pre-spec replace() semantics end to end
    leg = dataclasses.replace(base, imc_mode="sim", imc_bits=4)
    assert dataclasses.replace(leg, imc_mode="off").imc_fabric is None
    assert dataclasses.replace(leg, imc_bits=8).imc_fabric == FabricSpec(
        mode="sim")
    # mixing channels in one replace works when the legacy side is cleared
    assert dataclasses.replace(leg, fabric=spec,
                               imc_mode="off").imc_fabric == spec


# ------------------------------------------------- legacy kwargs removed
def test_legacy_kwargs_are_gone():
    """The pre-spec loose kwargs finished deprecation: they now raise
    TypeError like any unknown keyword, and the spec path is the only one."""
    from repro.models.common import dense, init_dense

    x, w = _xw(seed=8)
    with pytest.raises(TypeError):
        imc_matmul(x, w, bits=8, mode="sim", mismatch=True)
    with pytest.raises(TypeError):
        imc_matmul(x, w, use_kernel=True)
    p = init_dense(jax.random.key(0), 16, 8)
    xa = jax.random.normal(jax.random.key(1), (4, 16))
    with pytest.raises(TypeError):
        dense(p, xa, imc_mode="exact", imc_bits=8)
    lp = init_imc_linear(jax.random.key(0), 16, 8, use_bias=True)
    with pytest.raises(TypeError):  # old positional tail (bits, mode, kernel)
        imc_linear_apply(xa, lp["w"], lp["b"], 8, "sim", False)
    with pytest.raises(TypeError):
        apply_imc_linear(lp, xa, bits=4, mode="sim")
    with pytest.raises(ImportError):
        from repro.core.legacy import legacy_fabric_spec  # noqa: F401


def test_spec_path_serves_former_legacy_shapes():
    """Every mapping the shims used to provide is a one-line FabricSpec."""
    x, w = _xw(seed=9)
    key = jax.random.key(0)
    noisy = FabricSpec(mode="sim", backend="jnp",
                       noise=NoiseSpec(mismatch_sigma=C.MC_SIGMA_VK))
    y = fabric_matmul(x, w, noisy, key=key)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(imc_matmul(x, w, noisy, key=key)))
    # the old use_kernel=True + noise combination silently fell back to jnp;
    # the typed spec makes the engine explicit (and pallas + noise is now a
    # real engine of its own, not a fallback)
    assert noisy.resolve_backend() == "jnp" and noisy.noisy
    assert FabricSpec(mode="sim", backend="pallas").resolve_backend() == \
        "pallas"


# -------------------------------------------------------------- jit cache
def test_equal_specs_share_one_jit_entry():
    x, w = _xw(m=2, k=16, n=4, seed=10)
    spec_a = FabricSpec(bits_a=4, bits_w=4, mode="sim", backend="jnp")
    fabric_matmul(x, w, spec_a)
    n_before = fabric_matmul._cache_size()
    # a NEW but equal spec instance (incl. a canonicalized no-op NoiseSpec)
    spec_b = FabricSpec(bits_a=4, bits_w=4, mode="sim", backend="jnp",
                        noise=NoiseSpec())
    y = fabric_matmul(x, w, spec_b)
    assert fabric_matmul._cache_size() == n_before  # no recompile
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(fabric_matmul(x, w, spec_a)))
    # a genuinely different spec DOES add an entry
    fabric_matmul(x, w, FabricSpec(bits_a=4, bits_w=5, mode="sim",
                                   backend="jnp"))
    assert fabric_matmul._cache_size() == n_before + 1
