"""Telemetry subsystem tests.

Registry mechanics (histogram percentile accuracy, disabled-mode no-ops and
their cost), span recording + Chrome trace export, snapshot/markdown/bench
exporters, the straggler monitor's true-median regression, and the serving
SLO integration: TTFT/TPOT/occupancy recorded on the mixed-length ragged
schedule WITHOUT breaking the zero-steady-state-retrace guarantee.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.launch.engine import Engine
from repro.launch.server import Request, Server
from repro.models.model import init_params
from repro.runtime.straggler import StragglerConfig, StragglerMonitor, _median
from repro.telemetry import (Registry, SpanRecorder, clock, get_registry,
                             merge_into_bench, serving_slos, snapshot,
                             to_markdown)

LENGTHS = (7, 16, 33, 12, 5)  # same ragged schedule the paged-KV tests pin
MAX_NEW = 6


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("qwen2.5-3b"))


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.key(0), cfg)


# ---------------------------------------------------------------- registry
def test_histogram_percentiles_track_known_distribution():
    reg = Registry()
    h = reg.histogram("t")
    vals = np.arange(1, 1001) / 1000.0  # uniform 1 ms .. 1 s
    for v in vals:
        h.observe(float(v))
    assert h.count == 1000 and h.min == 0.001 and h.max == 1.0
    for q, true in ((50, 0.5), (95, 0.95), (99, 0.99)):
        est = h.percentile(q)
        assert abs(est - true) / true < 0.15, \
            f"p{q}: {est} vs true {true} (log-bucket error bound exceeded)"
    s = h.summary()
    assert s["count"] == 1000 and abs(s["mean"] - vals.mean()) < 1e-9


def test_histogram_single_sample_is_exact_and_outliers_clamp():
    reg = Registry()
    h = reg.histogram("t")
    h.observe(0.0123)
    # min/max clamping makes the covering bucket degenerate -> exact
    assert h.percentile(50) == pytest.approx(0.0123)
    h2 = reg.histogram("wild")
    h2.observe(1e-9)  # below lo
    h2.observe(1e6)  # above hi
    assert h2.count == 2 and h2.min == 1e-9 and h2.max == 1e6
    assert reg.histogram("empty").percentile(50) is None


def test_registry_names_are_typed_and_stable():
    reg = Registry()
    c = reg.counter("x")
    c.inc(3)
    assert reg.counter("x") is c and c.value == 3
    g = reg.gauge("depth")
    g.set(2.0)
    g.set(1.0)
    assert g.value == 1.0 and g.hwm == 2.0
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")
    snap = reg.snapshot()
    assert snap["counters"]["x"] == 3
    assert snap["gauges"]["depth"] == {"value": 1.0, "hwm": 2.0}


def test_disabled_mode_records_nothing():
    reg = Registry()
    c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
    c.inc()
    h.observe(0.5)
    with reg.disabled():
        c.inc(100)
        g.set(9.0)
        h.observe(0.5)
        with SpanRecorder(reg).span("quiet"):
            pass
    assert reg.enabled  # context restores the flag
    assert c.value == 1 and g.value == 0.0 and h.count == 1
    off = Registry(enabled=False)
    off.counter("n").inc()
    assert off.counter("n").value == 0


def test_reset_zeroes_in_place_without_orphaning_handles():
    """Components cache metric handles at construction; reset() must zero
    them, not replace them (or post-reset records vanish from snapshots)."""
    reg = Registry()
    c, h = reg.counter("c"), reg.histogram("h")
    c.inc(5)
    h.observe(0.5)
    reg.reset()
    assert reg.counter("c") is c and c.value == 0
    assert h.count == 0 and h.percentile(50) is None
    c.inc()
    h.observe(0.25)  # the cached handles still feed the snapshot
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 1
    assert snap["histograms"]["h"]["count"] == 1


def test_disabled_record_path_is_cheap_enough_for_decode_loops():
    """Acceptance bound: per-record cost with telemetry off stays under 2%
    of a (very fast) 1 ms decode step."""
    reg = Registry(enabled=False)
    h, c = reg.histogram("h"), reg.counter("c")
    n = 100_000
    t0 = clock()
    for _ in range(n):
        h.observe(1e-3)
        c.inc()
    per_record = (clock() - t0) / (2 * n)
    assert per_record < 0.02 * 1e-3, \
        f"disabled record path costs {per_record * 1e9:.0f} ns"


# ------------------------------------------------------------------- spans
def test_spans_nest_and_export_chrome_trace(tmp_path):
    reg = Registry()
    rec = SpanRecorder(reg)
    with rec.span("outer", phase="a"):
        with rec.span("inner"):
            pass
    assert [e["name"] for e in rec.events] == ["inner", "outer"]
    trace = rec.chrome_trace()
    # chronological order + the complete-event shape Perfetto expects
    assert [e["name"] for e in trace["traceEvents"]] == ["outer", "inner"]
    outer, inner = trace["traceEvents"]
    assert outer["ph"] == "X" and outer["args"] == {"phase": "a"}
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    path = rec.export(str(tmp_path / "trace.json"))
    loaded = json.load(open(path))
    assert loaded["traceEvents"] and loaded["displayTimeUnit"] == "ms"


# --------------------------------------------------------------- exporters
def test_markdown_and_bench_merge_round_trip():
    reg = Registry()
    reg.counter("server.admitted").inc(4)
    reg.gauge("server.queue_depth").set(2)
    reg.histogram("server.ttft_s").observe(0.05)
    md = to_markdown(registry=reg)
    assert "server.admitted" in md and "server.ttft_s" in md
    rec = merge_into_bench({"tokens_per_s": 10.0}, reg)
    assert rec["telemetry"]["counters"]["server.admitted"] == 4
    json.dumps(rec)  # BENCH_imc.json-serializable as-is


def test_serving_slos_are_none_without_a_server():
    slos = serving_slos(Registry())
    assert slos == {"ttft_ms": None, "tpot_ms": None, "occupancy_peak": None}


# ----------------------------------------------------------- registry merge
def test_merge_histogram_percentiles_are_exact_bucket_addition():
    """Fleet percentiles must equal percentiles of the union of samples —
    bucket counts add exactly; nothing is approximated at merge time."""
    rng = np.random.default_rng(7)
    samples = rng.uniform(1e-4, 5.0, size=240)
    ref, parts = Registry(), [Registry() for _ in range(3)]
    for i, v in enumerate(samples):
        ref.histogram("step_s").observe(float(v))
        parts[i % 3].histogram("step_s").observe(float(v))
    merged = Registry.merge(*[p.snapshot() for p in parts])
    m = merged.snapshot()["histograms"]["step_s"]
    r = ref.snapshot()["histograms"]["step_s"]
    assert m["count"] == r["count"] == 240
    for q in ("p50", "p95", "p99"):
        assert m[q] == r[q], f"{q}: merged {m[q]} != as-if-one {r[q]}"
    assert m["min"] == r["min"] and m["max"] == r["max"]
    assert m["mean"] == pytest.approx(r["mean"])
    assert m["buckets"] == r["buckets"]


def test_merge_counters_sum_and_gauge_high_water_is_max():
    a, b = Registry(), Registry()
    a.counter("served").inc(3)
    b.counter("served").inc(5)
    a.gauge("depth").set(4.0)
    a.gauge("depth").set(1.0)  # a: value 1.0, hwm 4.0
    b.gauge("depth").set(2.5)  # b: value 2.5, hwm 2.5
    snap = Registry.merge(a.snapshot(), b.snapshot()).snapshot()
    assert snap["counters"]["served"] == 8
    assert snap["gauges"]["depth"] == {"value": 3.5, "hwm": 4.0}


def test_merge_identity_under_single_snapshot():
    reg = Registry()
    reg.counter("c").inc(4)
    reg.gauge("g").set(1.5)
    for v in (0.001, 0.01, 0.1, 7.0):
        reg.histogram("h").observe(v)
    reg.histogram("empty")  # zero-count histograms survive the round trip
    merged = Registry.merge(reg.snapshot())
    assert merged.snapshot() == reg.snapshot()


def test_merge_rejects_mismatched_histogram_layouts():
    a, b = Registry(), Registry()
    a.histogram("h").observe(0.5)
    b.histogram("h", lo=1e-3, hi=10.0).observe(0.5)
    with pytest.raises(ValueError, match="layout"):
        Registry.merge(a.snapshot(), b.snapshot())


# ------------------------------------------------- straggler true median
def test_straggler_median_is_true_median():
    assert _median([0.1, 0.4]) == pytest.approx(0.25)
    assert _median([0.1, 0.1, 0.2, 0.3]) == pytest.approx(0.15)
    assert _median([0.3, 0.1, 0.2]) == 0.2


@pytest.mark.parametrize("times,slow", [
    ({0: 0.1, 1: 0.4}, 1),  # 2 hosts: upper-middle "median" (0.4) hides it
    ({0: 0.1, 1: 0.1, 2: 0.2, 3: 0.3}, 3),  # 4 hosts: 0.2 vs true 0.15
])
def test_straggler_flags_slow_host_in_even_fleets(times, slow):
    """Regression: with the old upper-middle median the threshold lands at
    or above the straggler's own EWMA and it is never flagged."""
    mon = StragglerMonitor(cfg=StragglerConfig(threshold=1.5, patience=3))
    for _ in range(mon.cfg.patience + 2):
        flagged = mon.record_step(dict(times))
    assert mon.swaps == [slow] and flagged == []
    old_median = sorted(times.values())[len(times) // 2]
    assert times[slow] <= mon.cfg.threshold * old_median, \
        "test vector no longer distinguishes true median from upper-middle"


def test_replace_host_drops_stats_and_reseeds_from_first_sample():
    """Regression: replace_host used to reset to HostStats(ewma_time=0.0),
    which (a) biased the fleet median low until the EWMA warmed back up and
    (b) left the per-host EWMA gauge showing the dead host's last estimate.
    The entry must be DROPPED: the swapped-in host re-seeds from its first
    post-swap sample and the gauge reads 0 until then."""
    mon = StragglerMonitor(cfg=StragglerConfig(threshold=1.5, patience=3))
    times = {0: 0.1, 1: 0.1, 2: 0.12, 3: 0.5}
    for _ in range(mon.cfg.patience + 2):
        mon.record_step(dict(times))
    assert mon.swaps == [3]
    mon.replace_host(3)
    assert 3 not in mon.hosts, "entry must be dropped, not zeroed"
    assert get_registry().gauge("straggler.ewma_s.host3").value == 0.0
    # until the spare reports, the median covers the survivors only — a
    # zeroed entry would drag it to 0.055 and mask host 2 as a "straggler"
    assert _median([s.ewma_time for s in mon.hosts.values()]) == \
        pytest.approx(0.1)
    # first post-swap sample seeds the EWMA at the sample itself, not at
    # ewma * 0 + (1 - ewma) * sample
    mon.record_step({**times, 3: 0.1})
    st = mon.hosts[3]
    assert st.ewma_time == pytest.approx(0.1)
    assert st.strikes == 0 and not st.flagged
    # a healthy replacement never re-flags (and nobody else does either)
    for _ in range(mon.cfg.patience + 2):
        mon.record_step({**times, 3: 0.1})
    assert mon.swaps == [3]


# ------------------------------------------- serving SLOs, end to end
def test_server_slos_on_ragged_schedule_without_retraces(cfg, params):
    reg = Registry()
    eng = Engine(registry=reg)
    assert reg.enabled
    with eng.activate():
        server = Server(cfg, params, engine=eng, slots=2, block_size=8,
                        buckets=(16, 48), max_seq_len=48 + MAX_NEW)
        prompts = [np.random.default_rng(0).integers(
            0, cfg.vocab_size, size=n).astype(np.int32) for n in LENGTHS]
        for p in prompts:
            server.submit(Request(p, max_new_tokens=MAX_NEW))
        server.drain()
        warm = eng.stats.traces
        for p in reversed(prompts):
            server.submit(Request(p, max_new_tokens=MAX_NEW))
        handles = server.drain()
    assert all(h.done for h in handles)
    # telemetry-on steady state stays data-only (the hard constraint)
    assert eng.stats.traces == warm, \
        "telemetry recording must not retrace the compiled steps"

    n = 2 * len(LENGTHS)
    snap = snapshot(reg)
    assert snap["counters"]["server.admitted"] == n
    assert snap["histograms"]["server.ttft_s"]["count"] == n
    assert snap["histograms"]["server.tpot_s"]["count"] == n
    occ = snap["gauges"]["server.block_occupancy"]
    assert 0.0 < occ["hwm"] <= 1.0
    assert occ["value"] == 0.0, "drained pool must read empty"
    assert snap["counters"]["server.decode_tokens"] == n * (MAX_NEW - 1)
    assert snap["gauges"]["server.decode_tokens_per_s"]["value"] > 0

    slos = serving_slos(reg)
    assert slos["ttft_ms"] > 0 and slos["tpot_ms"] > 0
    assert slos["occupancy_peak"] == round(occ["hwm"], 3)
    # engine-side instrumentation rode along on the same registry
    assert snap["counters"]["engine.compiles"] >= 3
    assert snap["histograms"]["engine.step_s.decode"]["count"] > 0


def test_global_registry_is_the_default_feed():
    eng = Engine()
    assert eng.registry is get_registry()
