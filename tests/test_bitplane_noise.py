"""Noisy bitplane_mac kernel: statistical parity, determinism, independence.

The fused noisy kernel draws from a different PRNG stream than the keyed jnp
engine (Mosaic hardware PRNG / counter-hash vs threefry), so cross-engine
agreement is pinned STATISTICALLY — moments and quantiles of the decode
deviation over >= 1k iid trials, and detuned-threshold error-rate bands
against an independent numpy Monte-Carlo of the exact in-kernel semantics —
never bitwise.  Determinism (same fabric key -> identical outputs) and
stream independence across grid positions ARE exact properties and are
asserted exactly.

Trials technique: replicating one operand row M times makes every output row
an iid draw of the same decode distribution (noise is elementwise), so a
single kernel launch yields M x N samples.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitserial import bitserial_matmul_unsigned
from repro.core.decoder import thresholds as core_thresholds
from repro.core.rbl import rbl_voltage
from repro.kernels.bitplane_mac import ops as bp_ops
from repro.kernels.bitplane_mac.ops import bitplane_mac_noisy

SIGMAS = dict(mismatch_sigma=0.3, comparator_offset_sigma=0.03)


def _trials(bits=4, m=256, k=64, n=8, seed=0):
    """Replicated-row operands: every output row is an iid noise trial."""
    rng = np.random.default_rng(seed)
    row = rng.integers(0, 1 << bits, size=(1, k)).astype(np.int32)
    ua = jnp.asarray(np.repeat(row, m, axis=0))
    uw = jnp.asarray(rng.integers(0, 1 << bits, size=(k, n)).astype(np.int32))
    return ua, uw, np.asarray(ua) @ np.asarray(uw)


# ---------------------------------------------------------- determinism
def test_same_key_identical_different_keys_differ():
    ua, uw, _ = _trials()
    y1 = bitplane_mac_noisy(ua, uw, jax.random.key(0), bits_a=4, bits_w=4,
                            **SIGMAS)
    y2 = bitplane_mac_noisy(ua, uw, jax.random.key(0), bits_a=4, bits_w=4,
                            **SIGMAS)
    y3 = bitplane_mac_noisy(ua, uw, jax.random.key(1), bits_a=4, bits_w=4,
                            **SIGMAS)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert not np.array_equal(np.asarray(y1), np.asarray(y3))


def test_raw_uint32_key_matches_typed_key():
    ua, uw, _ = _trials(m=32)
    yt = bitplane_mac_noisy(ua, uw, jax.random.key(5), bits_a=4, bits_w=4,
                            **SIGMAS)
    yr = bitplane_mac_noisy(ua, uw, jax.random.PRNGKey(5), bits_a=4,
                            bits_w=4, **SIGMAS)
    np.testing.assert_array_equal(np.asarray(yt), np.asarray(yr))


def test_zero_noise_spec_is_exact():
    ua, uw, exact = _trials(m=16)
    out = bitplane_mac_noisy(ua, uw, jax.random.key(0), bits_a=4, bits_w=4)
    np.testing.assert_array_equal(np.asarray(out), exact)


# -------------------------------------------- moment/quantile parity
def test_moment_and_quantile_parity_vs_jnp_oracle():
    """Kernel and keyed jnp engine draw from the SAME deviation distribution.

    256 trial rows x 8 columns = 2048 samples per engine; the oracle runs
    ``rbl_mode="physics"`` (the kernel's in-register voltage model).
    """
    ua, uw, exact = _trials(bits=4, m=256, k=64, n=8)
    ok = bitplane_mac_noisy(ua, uw, jax.random.key(0), bits_a=4, bits_w=4,
                            **SIGMAS)
    oj = bitserial_matmul_unsigned(
        ua, uw, bits_a=4, bits_w=4, mode="sim", key=jax.random.key(1),
        rbl_mode="physics", **SIGMAS)
    dk = (np.asarray(ok) - exact).ravel()
    dj = (np.asarray(oj) - exact).ravel()
    s = dj.std()
    assert s > 0  # the noise must actually flip decodes at these sigmas
    assert abs(dk.mean() - dj.mean()) < 0.15 * s
    assert 0.85 < dk.std() / s < 1.15
    for q in (10, 25, 50, 75, 90):
        assert abs(np.percentile(dk, q) - np.percentile(dj, q)) < 0.15 * s


def test_detuned_threshold_error_rate_band():
    """Single plane pair + single group: the output IS the decoded count, so
    the error rate under detuned references must land in the band of an
    independent numpy Monte-Carlo of the in-kernel noise semantics."""
    rows, m, n, k_true = 8, 256, 128, 4
    a = np.zeros((m, rows), np.int32)
    a[:, :k_true] = 1
    ua, uw = jnp.asarray(a), jnp.asarray(np.ones((rows, n), np.int32))
    good = np.asarray(core_thresholds(rows, mode="physics"))
    ms, cs = 0.2, 0.02
    rng = np.random.default_rng(12345)
    samples = 200_000
    k_eff = k_true + ms * np.sqrt(k_true) * rng.standard_normal(samples)
    v = np.asarray(rbl_voltage(jnp.asarray(k_eff, jnp.float32), rows=rows,
                               mode="physics"))
    for detune in (0.0, 0.4 * 0.216845):  # centered / 0.4-level corner shift
        thr = good + detune
        out = bitplane_mac_noisy(
            ua, uw, jax.random.key(3), jnp.asarray(thr), bits_a=1, bits_w=1,
            mismatch_sigma=ms, comparator_offset_sigma=cs)
        err_kernel = float((np.asarray(out) != k_true).mean())
        dec = (v[:, None] <= (thr[None, :] + cs * rng.standard_normal(
            (samples, rows)))).sum(1)
        err_mc = float((dec != k_true).mean())
        assert err_mc > 0.05  # the regime is genuinely noisy
        assert abs(err_kernel - err_mc) < 0.03, (detune, err_kernel, err_mc)


def test_k_padding_groups_draw_no_noise():
    """K pads up to the bk tile; padded zero-count groups must be masked —
    otherwise comparator offset flips them and the sum drifts from the
    oracle's (which never has those groups)."""
    rows, m, n = 8, 64, 16
    a = np.zeros((m, rows), np.int32)
    a[:, :4] = 1
    ua, uw = jnp.asarray(a), jnp.asarray(np.ones((rows, n), np.int32))
    # bk=256 -> 31 padded groups beside the single real one; big offset noise
    out = bitplane_mac_noisy(ua, uw, jax.random.key(0), bits_a=1, bits_w=1,
                             comparator_offset_sigma=0.05, bk=256)
    oj = bitserial_matmul_unsigned(
        ua, uw, bits_a=1, bits_w=1, mode="sim", key=jax.random.key(1),
        rbl_mode="physics", comparator_offset_sigma=0.05)
    dk = np.asarray(out) - 4
    dj = np.asarray(oj) - 4
    # with unmasked padding the kernel mean would sit tens of counts high
    assert abs(dk.mean() - dj.mean()) < 0.5


# ----------------------------------------------------- independence
def test_noise_independent_across_trial_slots():
    ua, uw, _ = _trials(bits=4, m=64, k=64, n=8)
    out = np.asarray(bitplane_mac_noisy(ua, uw, jax.random.key(0), bits_a=4,
                                        bits_w=4, **SIGMAS))
    # identical input rows, so any variation between rows is noise — and
    # with per-element streams the 64 trials cannot all coincide
    assert np.unique(out, axis=0).shape[0] > 1


def test_noise_independent_across_m_tiles():
    """Two M-tiles with identical contents: the grid-step fold must give
    them different streams, else every tile decodes identically."""
    rows = 8
    a = np.zeros((16, rows), np.int32)
    a[:, :4] = 1
    ua = jnp.asarray(a)
    uw = jnp.asarray(np.ones((rows, 128), np.int32))
    out = np.asarray(bitplane_mac_noisy(
        ua, uw, jax.random.key(2), bits_a=1, bits_w=1, bm=8, bn=128, bk=64,
        mismatch_sigma=0.4, comparator_offset_sigma=0.05))
    assert not np.array_equal(out[:8], out[8:])  # tile i=0 vs i=1


def test_noise_independent_across_k_group_steps():
    """Two identical K-blocks in separate grid steps (bk splits them): if the
    kk step fold were broken both halves would draw the SAME deviations and
    every total deviation would be even."""
    rows, m, n = 8, 64, 64
    half = np.zeros((m, rows), np.int32)
    half[:, :4] = 1
    ua = jnp.asarray(np.concatenate([half, half], axis=1))  # K = 16
    uw = jnp.asarray(np.ones((2 * rows, n), np.int32))
    out = np.asarray(bitplane_mac_noisy(
        ua, uw, jax.random.key(4), bits_a=1, bits_w=1, bm=64, bn=64, bk=8,
        mismatch_sigma=0.4, comparator_offset_sigma=0.05))
    dev = out - 8
    assert np.any(dev % 2 != 0)


def test_noise_independent_across_plane_pairs():
    """Activation value 3 = bits 11: both planes see identical counts.  If
    plane pairs shared a stream, deviation = d*1 + d*2 would always divide
    by 3."""
    rows, m, n = 8, 64, 64
    a = np.zeros((m, rows), np.int32)
    a[:, :4] = 3
    ua = jnp.asarray(a)
    uw = jnp.asarray(np.ones((rows, n), np.int32))
    exact = np.asarray(ua) @ np.asarray(uw)
    out = np.asarray(bitplane_mac_noisy(
        ua, uw, jax.random.key(6), bits_a=2, bits_w=1,
        mismatch_sigma=0.4, comparator_offset_sigma=0.05))
    dev = out - exact
    assert np.any(dev % 3 != 0)


# -------------------------------------------------- fabric dispatch
def test_fabric_noisy_pallas_dispatches_to_fused_kernel():
    from repro.core.fabric import (Fabric, FabricSpec, NoiseSpec,
                                   resolve_engine)

    spec = FabricSpec(mode="sim", backend="pallas",
                      noise=NoiseSpec(mismatch_sigma=0.05))
    assert resolve_engine(spec).__name__ == "_sim_pallas_noisy"
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    fab = Fabric(spec)
    y1 = fab.matmul(x, w, key=jax.random.key(0))
    y2 = fab.matmul(x, w, key=jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert np.isfinite(np.asarray(y1)).all()
    # jnp oracle at the same spec stays available and statistically close
    yj = Fabric(spec.replace(backend="jnp")).matmul(x, w,
                                                    key=jax.random.key(0))
    ref = np.linalg.norm(np.asarray(yj))
    assert np.linalg.norm(np.asarray(y1) - np.asarray(yj)) < 0.2 * ref + 1e-6


def test_fabric_noisy_moment_parity_across_engines():
    """End-to-end fabric path (quantize -> noisy GEMM -> dequant): pallas
    and jnp engines agree on the deviation moments over replicated rows."""
    from repro.core.fabric import Fabric, FabricSpec, NoiseSpec

    rng = np.random.default_rng(7)
    row = rng.normal(size=(1, 64)).astype(np.float32)
    x = jnp.asarray(np.repeat(row, 128, axis=0))
    w = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    noise = NoiseSpec(mismatch_sigma=0.3, comparator_offset_sigma=0.03)
    yk = Fabric(FabricSpec(mode="sim", backend="pallas", noise=noise)).matmul(
        x, w, key=jax.random.key(0))
    yj = Fabric(FabricSpec(mode="sim", backend="jnp", noise=noise)).matmul(
        x, w, key=jax.random.key(1))
    ye = Fabric(FabricSpec(mode="exact")).matmul(x, w)
    dk = (np.asarray(yk) - np.asarray(ye)).ravel()
    dj = (np.asarray(yj) - np.asarray(ye)).ravel()
    s = dj.std()
    assert s > 0
    assert abs(dk.mean() - dj.mean()) < 0.25 * s
    assert 0.75 < dk.std() / s < 1.33


# ------------------------------------------------- PRNG-less fallback
def test_fallback_warns_once_and_counts(monkeypatch):
    from repro.kernels.compat import KernelCaps
    from repro.telemetry import get_registry

    monkeypatch.setattr(bp_ops, "kernel_caps",
                        lambda it=None: KernelCaps(interpret=False,
                                                   prng=False))
    monkeypatch.setattr(bp_ops, "_WARNED_PRNG_FALLBACK", False)
    ua, uw, _ = _trials(bits=4, m=8, k=16, n=4)
    counter = get_registry().counter("bitplane_mac.noisy_jnp_fallback")
    before = counter.value
    with pytest.warns(RuntimeWarning, match="in-kernel PRNG"):
        y1 = bitplane_mac_noisy(ua, uw, jax.random.key(0), bits_a=4,
                                bits_w=4, **SIGMAS)
    assert counter.value == before + 1
    # engine switch, not a silent no-op: results match the jnp oracle bitwise
    oracle = bitserial_matmul_unsigned(
        ua, uw, bits_a=4, bits_w=4, mode="sim", key=jax.random.key(0),
        rbl_mode="physics", **SIGMAS)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(oracle))
    # second call: counted again, but the warning fires only once
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        bitplane_mac_noisy(ua, uw, jax.random.key(0), bits_a=4, bits_w=4,
                           **SIGMAS)
    assert counter.value == before + 2
