"""MAC-derived logic: interpret Boolean functions from the decoded MAC count.

Paper §III-B..E: with m rows activated, a single MAC evaluation yields
    AND  = (count == m)          NAND = !AND
    OR   = (count > 0)           NOR  = !OR
    XOR  = parity(count)         XNOR = !XOR     (m=2: count==1, as Table II)
    SUM  = XOR, CARRY = AND      (1-bit addition, m=2)
simultaneously, with no additional logic circuitry.  8 columns evaluated in
parallel give bitwise 8-bit operations: :func:`logic_word` runs one packed
word per macro row-pair activation (each bit position is a column), and
:func:`add_nbit` chains :func:`add_1bit` into a ripple-carry adder — two MAC
evaluations per bit (half-adder pair), the carry read off the count.

Word-level functions take an optional ``decode`` callable (counts -> counts)
so the :class:`~repro.core.fabric.Fabric` facade can route every column's
2-operand count through the spec's analog decode path (voltage + comparator
model, optionally noisy) instead of the ideal identity.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

OPS = ("AND", "NAND", "OR", "NOR", "XOR", "XNOR", "SUM", "CARRY")
WORD_OPS = ("AND", "NAND", "OR", "NOR", "XOR", "XNOR")


def logic_from_count(count, m: int = 2):
    """All MAC-derived logic outputs for an m-operand evaluation.

    ``count``: int array of decoded MAC counts (any shape).
    Returns dict of uint8 arrays of the same shape.
    """
    count = jnp.asarray(count, jnp.int32)
    and_ = (count == m).astype(jnp.uint8)
    or_ = (count > 0).astype(jnp.uint8)
    xor = (count % 2).astype(jnp.uint8)  # == (count==1) for m=2 (Table II)
    return {
        "AND": and_, "NAND": 1 - and_,
        "OR": or_, "NOR": 1 - or_,
        "XOR": xor, "XNOR": 1 - xor,
        "SUM": xor, "CARRY": and_,
    }


def add_1bit(count):
    """1-bit full-adder outputs from a 2-row MAC evaluation (paper §III-E)."""
    out = logic_from_count(count, m=2)
    return out["SUM"], out["CARRY"]


def truth_table_counts():
    """MAC counts for the four 2-operand input patterns (Table II rows)."""
    a = jnp.array([0, 0, 1, 1], jnp.int32)
    b = jnp.array([0, 1, 0, 1], jnp.int32)
    return a + b  # for 1-bit operands, count = A + B


# ------------------------------------------------------------- word level
def unpack_word(x, bits: int = 8):
    """Packed uints -> bit planes: (...,) -> (..., bits) uint8, LSB first."""
    x = jnp.asarray(x, jnp.int32)
    shifts = jnp.arange(bits, dtype=jnp.int32)
    return ((x[..., None] >> shifts) & 1).astype(jnp.uint8)


def pack_word(planes, dtype=None):
    """Bit planes -> packed uints: (..., bits) uint8 -> (...,) ``dtype``.

    ``dtype=None`` picks the narrowest unsigned type that holds ``bits``.
    """
    bits = planes.shape[-1]
    if dtype is None:
        dtype = (jnp.uint8 if bits <= 8
                 else jnp.uint16 if bits <= 16 else jnp.uint32)
    weights = jnp.left_shift(1, jnp.arange(bits, dtype=jnp.int32))
    return jnp.sum(planes.astype(jnp.int32) * weights, axis=-1).astype(dtype)


def _word_counts(a, b, bits: int):
    """Per-column 2-operand MAC counts for packed words (one row pair)."""
    return (unpack_word(a, bits).astype(jnp.int32)
            + unpack_word(b, bits).astype(jnp.int32))


def logic_word(a, b, op: str, *, bits: int = 8,
               decode: Optional[Callable] = None):
    """Bitwise ``op`` over packed ``bits``-wide words (paper §III, Table II).

    Each bit position is one macro column; the whole word evaluates in a
    single 2-row MAC activation, so e.g. uint8 AND/XOR/NOR come out of one
    cycle.  ``decode`` passes every column's count through the (modeled)
    analog path; the default is the ideal digital count.
    """
    op = op.upper()
    if op not in WORD_OPS:
        raise ValueError(f"op must be one of {WORD_OPS}, got {op!r}")
    count = _word_counts(a, b, bits)
    if decode is not None:
        count = decode(count)
    return pack_word(logic_from_count(count, m=2)[op])


def add_nbit(a, b, *, bits: int = 8, decode: Optional[Callable] = None):
    """Ripple-carry addition of packed ``bits``-wide words via MAC adds.

    Two :func:`add_1bit` evaluations per bit (half-adder pair: operand bits,
    then sum+carry-in); the stage carries combine with an OR read off the
    same counts.  Returns ``(sum mod 2**bits, carry_out)`` as uint8 arrays —
    exactly the paper's §III-E multi-bit extension of the 1-bit adder.
    """
    dec = decode if decode is not None else (lambda c: c)
    pa = unpack_word(a, bits).astype(jnp.int32)
    pb = unpack_word(b, bits).astype(jnp.int32)
    carry = jnp.zeros(jnp.broadcast_shapes(pa.shape[:-1], pb.shape[:-1]),
                      jnp.uint8)
    outs = []
    for i in range(bits):
        s1, c1 = add_1bit(dec(pa[..., i] + pb[..., i]))
        s2, c2 = add_1bit(dec(s1.astype(jnp.int32)
                              + carry.astype(jnp.int32)))
        outs.append(s2)
        carry = jnp.bitwise_or(c1, c2)
    return pack_word(jnp.stack(outs, axis=-1)), carry
