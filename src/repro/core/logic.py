"""MAC-derived logic: interpret Boolean functions from the decoded MAC count.

Paper §III-B..E: with m rows activated, a single MAC evaluation yields
    AND  = (count == m)          NAND = !AND
    OR   = (count > 0)           NOR  = !OR
    XOR  = parity(count)         XNOR = !XOR     (m=2: count==1, as Table II)
    SUM  = XOR, CARRY = AND      (1-bit addition, m=2)
simultaneously, with no additional logic circuitry.  8 columns evaluated in
parallel give bitwise 8-bit operations.
"""
from __future__ import annotations

import jax.numpy as jnp

OPS = ("AND", "NAND", "OR", "NOR", "XOR", "XNOR", "SUM", "CARRY")


def logic_from_count(count, m: int = 2):
    """All MAC-derived logic outputs for an m-operand evaluation.

    ``count``: int array of decoded MAC counts (any shape).
    Returns dict of uint8 arrays of the same shape.
    """
    count = jnp.asarray(count, jnp.int32)
    and_ = (count == m).astype(jnp.uint8)
    or_ = (count > 0).astype(jnp.uint8)
    xor = (count % 2).astype(jnp.uint8)  # == (count==1) for m=2 (Table II)
    return {
        "AND": and_, "NAND": 1 - and_,
        "OR": or_, "NOR": 1 - or_,
        "XOR": xor, "XNOR": 1 - xor,
        "SUM": xor, "CARRY": and_,
    }


def add_1bit(count):
    """1-bit full-adder outputs from a 2-row MAC evaluation (paper §III-E)."""
    out = logic_from_count(count, m=2)
    return out["SUM"], out["CARRY"]


def truth_table_counts():
    """MAC counts for the four 2-operand input patterns (Table II rows)."""
    a = jnp.array([0, 0, 1, 1], jnp.int32)
    b = jnp.array([0, 1, 0, 1], jnp.int32)
    return a + b  # for 1-bit operands, count = A + B
