"""Quantization utilities for the bit-serial IMC MAC.

Symmetric linear quantization to signed ``bits``-wide integers in
[-(2^{b-1}-1), 2^{b-1}-1] (symmetric range avoids the -128 asymmetry), with
per-tensor (activations, dynamic) or per-channel (weights) scales.

Bit-plane view: the SRAM array stores/streams {0,1} bits, so signed operands
use offset-binary u = q + 2^{b-1} in [1, 2^b - 1], and the signed product is
recovered with rank-1 corrections:

  q_a . q_w = u_a . u_w - o * sum(u_w) - o * sum(u_a) + K * o^2,   o = 2^{b-1}
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class Quantized(NamedTuple):
    q: jnp.ndarray  # int8/int32 signed quantized values
    scale: jnp.ndarray  # broadcastable scale: x ~= q * scale


def quantize(x, bits: int = 8, axis=None, eps: float = 1e-8) -> Quantized:
    """Symmetric quantization; ``axis`` = reduction axes for the scale
    (None -> per-tensor). Keeps dims for broadcastable scales."""
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, eps) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return Quantized(q, scale.astype(jnp.float32))


def dequantize(qx: Quantized):
    return qx.q.astype(jnp.float32) * qx.scale


def to_offset_binary(q, bits: int = 8):
    """Signed q -> unsigned offset-binary u = q + 2^{b-1} (int32)."""
    return q.astype(jnp.int32) + (1 << (bits - 1))


def to_bitplanes(u, bits: int = 8):
    """Unsigned u -> stacked {0,1} planes, LSB first: uint8[bits, ...]."""
    u = u.astype(jnp.int32)
    shifts = jnp.arange(bits, dtype=jnp.int32).reshape((bits,) + (1,) * u.ndim)
    return ((u[None] >> shifts) & 1).astype(jnp.uint8)


def from_bitplanes(planes):
    """Inverse of :func:`to_bitplanes`."""
    bits = planes.shape[0]
    w = (1 << jnp.arange(bits, dtype=jnp.int32)).reshape(
        (bits,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes.astype(jnp.int32) * w, axis=0)


def signed_product_correction(u_a, u_w, bits_a: int = 8,
                              bits_w: int | None = None):
    """Rank-1 correction terms so that q_a.q_w is recovered from u_a.u_w.

    ``u_a``: int32[..., K] offset-binary activations at ``bits_a``,
    ``u_w``: int32[K, N] likewise at ``bits_w`` (defaults to ``bits_a``; the
    precisions may differ — reconfigurable-precision fabrics).  With
    o_a = 2^{bits_a-1}, o_w = 2^{bits_w-1}:

        q_a . q_w = u_a . u_w - o_a*sum(u_w) - o_w*sum(u_a) + K*o_a*o_w

    Returns the (..., N) array to be SUBTRACTED from the unsigned matmul.
    """
    o_a = 1 << (bits_a - 1)
    o_w = 1 << ((bits_w if bits_w is not None else bits_a) - 1)
    k_dim = u_w.shape[0]
    col = jnp.sum(u_w, axis=0)  # [N]
    row = jnp.sum(u_a, axis=-1, keepdims=True)  # [..., 1]
    return o_a * col + o_w * row - k_dim * o_a * o_w
