"""Bit-serial N-bit MAC over the IMC fabric (the paper's "M parallel N-bit MAC").

A multi-bit dot product decomposes into binary (bit-plane) dot products:

    a . w = sum_{p,q} 2^{p+q} sum_k a_k[p] * w_k[q]

The inner binary sum is exactly what the SRAM macro computes: K is tiled into
groups of ``rows`` (8), each group's popcount is a MAC count in [0, rows]
digitized by the comparator decoder, and groups/planes are shift-accumulated
digitally.  Two paths:

  * exact  — decode is the identity on [0, rows]; group sums telescope back to
             a plain integer matmul (used to prove digital equivalence).
  * sim    — per-group counts go through the analog path (voltage model ->
             thermometer decode), optionally with mismatch noise; this is the
             hardware-faithful emulation.

The engine is **plane-batched**: all ``bits_a x bits_w`` bit-plane pairs are
stacked into a leading batch axis, the group counts come out of ONE batched
contraction, the analog decode runs in ONE vectorized pass, and the final
shift-accumulate is a dot with a precomputed ``2^(p+q)`` weight vector.  The
seed's per-plane-pair Python loop survives as
:func:`bitserial_matmul_looped` — it is the bit-exact reference the batched
engine (and the fused Pallas kernel in ``repro.kernels.bitplane_mac``) are
tested against, dispatching 64 separate einsum+decode rounds instead of one.

PRNG discipline: plane pair ``(p, q)`` always consumes
``fold_in(key, p * bits_w + q)``, in the loop AND in the batch (where the
folded keys ride the plane axis through ``vmap``), so both engines draw
identical noise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import constants as C
from repro.core.decoder import decode_voltage
from repro.core.montecarlo import mc_count_noise
from repro.core.rbl import rbl_voltage


def _pad_to_groups(x, axis, rows):
    k = x.shape[axis]
    pad = (-k) % rows
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x


def group_counts(a_bits, w_bits, rows: int = C.ROWS):
    """Per-group binary MAC counts for ONE bit-plane pair.

    a_bits: uint8[..., K] RWL activation bits; w_bits: uint8[K, N] stored bits.
    Returns int32[..., G, N] counts with G = ceil(K/rows).
    """
    a = _pad_to_groups(a_bits.astype(jnp.int32), -1, rows)
    w = _pad_to_groups(w_bits.astype(jnp.int32), 0, rows)
    g = a.shape[-1] // rows
    a = a.reshape(a.shape[:-1] + (g, rows))
    w = w.reshape((g, rows) + w.shape[1:])
    # counts[..., g, n] = sum_r a[..., g, r] * w[g, r, n]
    return jnp.einsum("...gr,grn->...gn", a, w)


def batched_group_counts(a_planes, w_planes, rows: int = C.ROWS):
    """Group counts for ALL plane pairs in one contraction.

    a_planes: uint8[PA, ..., K]; w_planes: uint8[PW, K, N].
    Returns int32[PA*PW, ..., G, N], pair axis ordered i = p * PW + q.
    """
    a = _pad_to_groups(a_planes.astype(jnp.int32), -1, rows)
    w = _pad_to_groups(w_planes.astype(jnp.int32), 1, rows)
    pa, pw = a.shape[0], w.shape[0]
    g = a.shape[-1] // rows
    a = a.reshape(a.shape[:-1] + (g, rows))
    w = w.reshape((pw, g, rows) + w.shape[2:])
    # counts[p, q, ..., g, n] = sum_r a[p, ..., g, r] * w[q, g, r, n]
    counts = jnp.einsum("p...gr,qgrn->pq...gn", a, w)
    return counts.reshape((pa * pw,) + counts.shape[2:])


def fused_group_counts(a_planes, w_planes, rows: int = C.ROWS):
    """All plane-pair group counts as ONE G-batched GEMM, GEMM-friendly layout.

    a_planes: uint8[PA, M, K]; w_planes: uint8[PW, K, N].
    Returns int32[G, PA*M, PW*N]: per K-group, the (PA*M) x (PW*N) count
    matrix — every plane pair rides the GEMM's free dimensions, so the whole
    pyramid is one well-shaped contraction instead of PA*PW small ones, and
    the result needs NO transpose before the (elementwise) decode.
    """
    a = _pad_to_groups(a_planes.astype(jnp.int32), -1, rows)
    w = _pad_to_groups(w_planes.astype(jnp.int32), 1, rows)
    pa, m, k = a.shape
    pw, _, n = w.shape
    g = k // rows
    a = a.reshape(pa * m, g, rows)
    w = w.transpose(1, 0, 2).reshape(g, rows, pw * n)
    # counts[g, pm, qn] = sum_r a[pm, g, r] * w[g, r, qn]
    return jax.lax.dot_general(a, w, (((2,), (1,)), ((1,), (0,))),
                               preferred_element_type=jnp.int32)


def _decode_counts_inline(counts, *, rows: int, rbl_mode: str):
    """Noise-free analog decode without materializing the thermometer axis.

    Same comparisons as ``decoder.thermometer_code`` (count = #thresholds
    >= V, references descending), but accumulated across a static unroll of
    the ``rows`` comparators, so peak memory stays one counts-sized buffer
    instead of counts x rows.  Bit-identical to ``decode_voltage``.
    """
    from repro.core.decoder import thresholds as _thresholds

    v = rbl_voltage(counts.astype(jnp.float32), rows=rows, mode=rbl_mode)
    thr = _thresholds(rows, mode=rbl_mode)
    dec = jnp.zeros(v.shape, jnp.int32)
    for i in range(rows):  # static unroll: rows is small (8)
        dec = dec + (v <= thr[i]).astype(jnp.int32)
    return dec


def plane_pair_weights(bits_a: int, bits_w: int):
    """int32[bits_a * bits_w] shift weights 2^(p+q), i = p * bits_w + q."""
    p = jnp.arange(bits_a, dtype=jnp.int32)[:, None]
    q = jnp.arange(bits_w, dtype=jnp.int32)[None, :]
    return (jnp.int32(1) << (p + q)).reshape(-1)


def fold_plane_keys(key, n_pairs: int):
    """Per-plane-pair keys: keys[i] == fold_in(key, i) (the loop's schedule)."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(n_pairs, dtype=jnp.uint32))


def decode_group_counts(counts, *, mode: str = "exact", rows: int = C.ROWS,
                        key=None, mismatch: bool = False,
                        mismatch_sigma=None, comparator_offset_sigma=None,
                        rbl_mode: str = "lut"):
    """Pass group counts through the (modeled) analog decode path.

    mode="exact": identity (clipped) — the digital equivalent.
    mode="sim":   counts -> k_eff (+ mismatch) -> V_RBL -> comparators -> counts.

    ``mismatch=True`` draws device mismatch at the paper-calibrated sigma;
    ``mismatch_sigma`` overrides the sigma explicitly (``NoiseSpec`` path) and
    implies mismatch.  Passing ``mismatch_sigma=constants.MC_SIGMA_VK`` draws
    the very same samples as ``mismatch=True``.
    """
    if mode == "exact":
        return jnp.clip(counts, 0, rows)
    if mode != "sim":
        raise ValueError(mode)
    k_eff = counts.astype(jnp.float32)
    ckey = None
    mismatch = mismatch or mismatch_sigma is not None
    if mismatch or comparator_offset_sigma is not None:
        if key is None:
            raise ValueError("sim with noise requires a PRNG key")
    if mismatch:
        key, nkey = jax.random.split(key)
        k_eff = k_eff + mc_count_noise(nkey, counts.shape, counts,
                                       sigma_vk=mismatch_sigma)
        ckey = key
    elif comparator_offset_sigma is not None:
        ckey = key
    v = rbl_voltage(k_eff, rows=rows, mode=rbl_mode)
    return decode_voltage(v, rows=rows, mode=rbl_mode,
                          comparator_offset_sigma=comparator_offset_sigma,
                          key=ckey)


def _weighted_plane_sum(dec, weights):
    """sum_i weights[i] * sum_g dec[i, ..., g, n] -> [..., n] (int32)."""
    group_sums = jnp.sum(dec, axis=-2)  # [PP, ..., N]
    return jnp.tensordot(weights, group_sums, axes=(0, 0))


def bitserial_matmul_unsigned(u_a, u_w, *, bits_a: int = 8, bits_w: int = 8,
                              rows: int = C.ROWS, mode: str = "exact",
                              **decode_kw):
    """Unsigned bit-serial matmul — plane-batched engine.

    u_a: int32[..., K] in [0, 2^bits_a); u_w: int32[K, N) likewise.
    Returns int32[..., N] == u_a @ u_w when mode="exact".

    Noise-free (exact, or sim without mismatch/comparator noise): planes ride
    the free dimensions of ONE G-batched GEMM (:func:`fused_group_counts`),
    the analog decode runs inline without materializing the thermometer axis,
    and the ``2^(p+q)`` shift-accumulate is a single weighted reduction.

    Noisy sim: per-pair counts from :func:`batched_group_counts` go through
    the modular decode under ``vmap``, with the caller's key folded per plane
    pair INSIDE the batch — drawing the very same samples as
    :func:`bitserial_matmul_looped`.
    """
    from repro.core.quant import to_bitplanes

    base_key = decode_kw.pop("key", None)
    noisy = mode == "sim" and (
        decode_kw.get("mismatch") or
        decode_kw.get("mismatch_sigma") is not None or
        decode_kw.get("comparator_offset_sigma") is not None)
    if noisy:
        if base_key is None:
            raise ValueError("sim with noise requires a PRNG key")
        a_planes = to_bitplanes(u_a, bits_a)  # [PA, ..., K]
        w_planes = to_bitplanes(u_w, bits_w)  # [PW, K, N]
        counts = batched_group_counts(a_planes, w_planes, rows)
        keys = fold_plane_keys(base_key, bits_a * bits_w)
        dec = jax.vmap(
            lambda c, k: decode_group_counts(c, rows=rows, mode=mode, key=k,
                                             **decode_kw))(counts, keys)
        return _weighted_plane_sum(dec, plane_pair_weights(bits_a, bits_w))
    # noise-free fused engine
    decode_kw.pop("mismatch", None)
    decode_kw.pop("mismatch_sigma", None)
    decode_kw.pop("comparator_offset_sigma", None)
    rbl_mode = decode_kw.pop("rbl_mode", "lut")
    if decode_kw:
        raise TypeError(f"unknown decode kwargs: {sorted(decode_kw)}")
    batch = u_a.shape[:-1]
    k, n = u_a.shape[-1], u_w.shape[-1]
    m = 1
    for b in batch:
        m *= b
    a_planes = to_bitplanes(u_a.reshape(m, k), bits_a)  # [PA, M, K]
    w_planes = to_bitplanes(u_w, bits_w)                # [PW, K, N]
    counts = fused_group_counts(a_planes, w_planes, rows)  # [G, PA*M, PW*N]
    if mode == "exact":
        dec = jnp.clip(counts, 0, rows)
    elif mode == "sim":
        dec = _decode_counts_inline(counts, rows=rows, rbl_mode=rbl_mode)
    else:
        raise ValueError(mode)
    dec = dec.reshape(counts.shape[0], bits_a, m, bits_w, n)
    wmat = plane_pair_weights(bits_a, bits_w).reshape(bits_a, bits_w)
    out = jnp.einsum("gpmqn,pq->mn", dec, wmat)
    return out.reshape(*batch, n)


def bitserial_matmul_looped(u_a, u_w, *, bits_a: int = 8, bits_w: int = 8,
                            rows: int = C.ROWS, mode: str = "exact",
                            **decode_kw):
    """Seed reference engine: one einsum + decode per plane pair.

    Bit-identical to :func:`bitserial_matmul_unsigned` (including noise draws)
    but dispatches ``bits_a * bits_w`` separate rounds — kept as the oracle
    for the batched engine and the fused kernel, and as the loop baseline in
    ``benchmarks/bench_imc_throughput.py``.
    """
    from repro.core.quant import to_bitplanes

    a_planes = to_bitplanes(u_a, bits_a)  # [PA, ..., K]
    w_planes = to_bitplanes(u_w, bits_w)  # [PW, K, N]
    base_key = decode_kw.pop("key", None)
    out = None
    for p in range(bits_a):
        for q in range(bits_w):
            kw = dict(decode_kw)
            if base_key is not None:
                kw["key"] = jax.random.fold_in(base_key, p * bits_w + q)
            counts = group_counts(a_planes[p], w_planes[q], rows)
            dec = decode_group_counts(counts, rows=rows, mode=mode, **kw)
            part = jnp.sum(dec, axis=-2) << (p + q)  # sum over groups, shift
            out = part if out is None else out + part
    return out
