"""Bit-serial N-bit MAC over the IMC fabric (the paper's "M parallel N-bit MAC").

A multi-bit dot product decomposes into binary (bit-plane) dot products:

    a . w = sum_{p,q} 2^{p+q} sum_k a_k[p] * w_k[q]

The inner binary sum is exactly what the SRAM macro computes: K is tiled into
groups of ``rows`` (8), each group's popcount is a MAC count in [0, rows]
digitized by the comparator decoder, and groups/planes are shift-accumulated
digitally.  Two paths:

  * exact  — decode is the identity on [0, rows]; group sums telescope back to
             a plain integer matmul (used to prove digital equivalence).
  * sim    — per-group counts go through the analog path (voltage model ->
             thermometer decode), optionally with mismatch noise; this is the
             hardware-faithful emulation.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import constants as C
from repro.core.decoder import decode_voltage
from repro.core.montecarlo import mc_count_noise
from repro.core.rbl import rbl_voltage


def _pad_to_groups(x, axis, rows):
    k = x.shape[axis]
    pad = (-k) % rows
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x


def group_counts(a_bits, w_bits, rows: int = C.ROWS):
    """Per-group binary MAC counts.

    a_bits: uint8[..., K] RWL activation bits; w_bits: uint8[K, N] stored bits.
    Returns int32[..., G, N] counts with G = ceil(K/rows).
    """
    a = _pad_to_groups(a_bits.astype(jnp.int32), -1, rows)
    w = _pad_to_groups(w_bits.astype(jnp.int32), 0, rows)
    g = a.shape[-1] // rows
    a = a.reshape(a.shape[:-1] + (g, rows))
    w = w.reshape((g, rows) + w.shape[1:])
    # counts[..., g, n] = sum_r a[..., g, r] * w[g, r, n]
    return jnp.einsum("...gr,grn->...gn", a, w)


def decode_group_counts(counts, *, mode: str = "exact", rows: int = C.ROWS,
                        key=None, mismatch: bool = False,
                        comparator_offset_sigma=None, rbl_mode: str = "lut"):
    """Pass group counts through the (modeled) analog decode path.

    mode="exact": identity (clipped) — the digital equivalent.
    mode="sim":   counts -> k_eff (+ mismatch) -> V_RBL -> comparators -> counts.
    """
    if mode == "exact":
        return jnp.clip(counts, 0, rows)
    if mode != "sim":
        raise ValueError(mode)
    k_eff = counts.astype(jnp.float32)
    ckey = None
    if mismatch or comparator_offset_sigma is not None:
        if key is None:
            raise ValueError("sim with noise requires a PRNG key")
    if mismatch:
        import jax
        key, nkey = jax.random.split(key)
        k_eff = k_eff + mc_count_noise(nkey, counts.shape, counts)
        ckey = key
    elif comparator_offset_sigma is not None:
        ckey = key
    v = rbl_voltage(k_eff, rows=rows, mode=rbl_mode)
    return decode_voltage(v, rows=rows, mode=rbl_mode,
                          comparator_offset_sigma=comparator_offset_sigma,
                          key=ckey)


def bitserial_matmul_unsigned(u_a, u_w, *, bits_a: int = 8, bits_w: int = 8,
                              rows: int = C.ROWS, mode: str = "exact",
                              **decode_kw):
    """Unsigned bit-serial matmul via per-group decoded MAC counts.

    u_a: int32[..., K] in [0, 2^bits_a); u_w: int32[K, N) likewise.
    Returns int32[..., N] == u_a @ u_w when mode="exact".
    """
    from repro.core.quant import to_bitplanes

    import jax

    a_planes = to_bitplanes(u_a, bits_a)  # [PA, ..., K]
    w_planes = to_bitplanes(u_w, bits_w)  # [PW, K, N]
    base_key = decode_kw.pop("key", None)
    out = None
    for p in range(bits_a):
        for q in range(bits_w):
            kw = dict(decode_kw)
            if base_key is not None:
                kw["key"] = jax.random.fold_in(base_key, p * bits_w + q)
            counts = group_counts(a_planes[p], w_planes[q], rows)
            dec = decode_group_counts(counts, rows=rows, mode=mode, **kw)
            part = jnp.sum(dec, axis=-2) << (p + q)  # sum over groups, shift
            out = part if out is None else out + part
    return out
