"""Monte-Carlo device-mismatch model (paper Fig 6).

The paper's MC run (200 samples, MAC count 8) reports mean 437 fJ and sigma
48.72 fJ — random device mismatch during sensing.  We model per-discharge-path
charge mismatch: the energy of a count-k evaluation is

    E = E(0) + sum_{i=1..k} g_i * dE_i,     dE_i = E(i) - E(i-1) (Table III),
    g_i ~ N(MU_G, SIGMA_G)  iid per path,

with (MU_G, SIGMA_G) calibrated in closed form to the paper's (mean, sigma)
(see :mod:`repro.core.constants`).  The same g_i mismatch perturbs the
effective count seen by the decoder (k_eff = sum g_i), which is how decode
errors enter the analog-sim matmul path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import constants as C


def sample_path_gains(key, shape, *, sigma_g: float | None = None,
                      mu_g: float | None = None):
    """Per-discharge-path gain factors g ~ N(mu, sigma), clipped at 0."""
    sigma = C.MC_SIGMA_G if sigma_g is None else sigma_g
    mu = C.MC_MU_G if mu_g is None else mu_g
    return jnp.maximum(mu + sigma * jax.random.normal(key, shape), 0.0)


def mc_energy_fj(key, k: int, n_samples: int = C.MC_SAMPLES, **kw):
    """MC energy samples (fJ) for an evaluation with true count ``k``."""
    de = jnp.asarray(C.E_MAC_TABLE_FJ[1:] - C.E_MAC_TABLE_FJ[:-1], jnp.float32)
    g = sample_path_gains(key, (n_samples, k), **kw)
    return C.E_MAC_TABLE_FJ[0] + g @ de[:k]


def mc_count_noise(key, shape, k, *, sigma_vk: float | None = None):
    """Voltage-referred mismatch as additive noise on the effective count.

    ``k`` is the true count array (broadcast against ``shape``); noise stddev
    scales with sqrt(k) (independent per-path contributions).  Uses
    ``MC_SIGMA_VK`` — the small, margin-preserving voltage projection of
    mismatch (the paper's decode stays correct across MC/corners), NOT the
    energy-referred ``MC_SIGMA_G``.
    """
    k = jnp.asarray(k, jnp.float32)
    sigma = C.MC_SIGMA_VK if sigma_vk is None else sigma_vk
    z = jax.random.normal(key, shape)
    return sigma * jnp.sqrt(jnp.maximum(k, 0.0)) * z


def mc_stats(key, k: int = C.ROWS, n_samples: int = C.MC_SAMPLES, **kw):
    """(mean, std) of the MC energy distribution — Fig 6 reproduction."""
    e = mc_energy_fj(key, k, n_samples, **kw)
    return jnp.mean(e), jnp.std(e)
