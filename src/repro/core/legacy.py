"""The ONE home for every pre-FabricSpec compatibility shim.

PR 2 introduced :class:`repro.core.fabric.FabricSpec` as the single typed
entry point to the IMC stack; the loose per-call kwargs it replaced
(``imc_matmul(bits=, mode=, use_kernel=...)``, ``imc_linear_apply`` / the
positional triple, ``dense(imc_mode=, imc_bits=, use_kernel=...)``) keep
working for one release with a :class:`DeprecationWarning`.  This module
finishes that deprecation cycle by collapsing the mapping + warning logic
of all three surfaces into one documented place:

  * :func:`legacy_fabric_spec` — the semantic mapping from the old kwargs to
    a spec, preserving the old API's quirks (silent jnp fallback when
    ``use_kernel=True`` met noise; noise kwargs ignored in exact mode).
  * :func:`warn_deprecated_kwargs` — the one DeprecationWarning spelling,
    so the message (and its eventual removal) has a single site.
  * :func:`legacy_spec_from` — the guard used by every shimmed call site:
    rejects mixing ``spec=`` with legacy kwargs ("not both"), warns, maps.

Removal plan: the shimmed kwargs disappear from ``imc_matmul`` /
``imc_linear_apply`` / ``dense`` next release; this module then survives one
more release re-exporting only :func:`legacy_fabric_spec` for out-of-tree
callers, and finally goes away.  Identity with the old semantics is pinned
by ``tests/test_fabric.py`` (the ``match="FabricSpec"`` /
``match="not both"`` suite).
"""
from __future__ import annotations

import warnings
from typing import Iterable, Optional

from repro.core import constants as C
from repro.core.fabric import FabricSpec, NoiseSpec

__all__ = ["legacy_fabric_spec", "warn_deprecated_kwargs", "legacy_spec_from"]


def legacy_fabric_spec(*, mode: str = "exact", bits: int = 8,
                       bits_w: Optional[int] = None, rows: int = C.ROWS,
                       use_kernel: bool = False, mismatch: bool = False,
                       comparator_offset_sigma: Optional[float] = None,
                       ) -> FabricSpec:
    """Map the pre-FabricSpec loose kwargs onto a spec, old semantics intact.

    The old API silently fell back to the keyed jnp engine when
    ``use_kernel=True`` was combined with noise, and its exact path ignored
    the noise kwargs entirely; the mapping preserves both (the new spec API
    raises on those combos instead).
    """
    noise = None
    if mode == "sim" and (mismatch or comparator_offset_sigma is not None):
        noise = NoiseSpec(
            mismatch_sigma=C.MC_SIGMA_VK if mismatch else None,
            comparator_offset_sigma=comparator_offset_sigma)
    backend = "pallas" if use_kernel and noise is None else "jnp"
    return FabricSpec(bits_a=bits, bits_w=bits_w if bits_w is not None else bits,
                      rows=rows, mode=mode, backend=backend, noise=noise)


def warn_deprecated_kwargs(api: str, names: Iterable[str],
                           stacklevel: int = 3) -> None:
    """The ONE DeprecationWarning spelling for every pre-spec kwarg surface.

    Each legacy shim (``imc_matmul``, ``imc_linear_apply``, ``dense``) calls
    this so the message — and its eventual one-release removal — lives in a
    single place next to :func:`legacy_fabric_spec`.
    """
    warnings.warn(
        f"{api}({', '.join(sorted(names))}=...) is deprecated; pass a "
        "repro.core.fabric.FabricSpec as `spec` instead (one typed, "
        "hashable, jit-stable configuration object)",
        DeprecationWarning, stacklevel=stacklevel)


def legacy_spec_from(api: str, bits: Optional[int] = None,
                     mode: Optional[str] = None,
                     use_kernel: Optional[bool] = None,
                     stacklevel: int = 4) -> FabricSpec:
    """The (bits, mode, use_kernel) triple shared by ``imc_linear_apply`` /
    ``apply_imc_linear``: warn once, map onto a spec.  Call sites are
    responsible for the ``spec`` / legacy mutual-exclusion TypeError (its
    "not both" message is pinned by tests)."""
    legacy = {k: v for k, v in dict(bits=bits, mode=mode,
                                    use_kernel=use_kernel).items()
              if v is not None}
    warn_deprecated_kwargs(api, legacy, stacklevel=stacklevel)
    return legacy_fabric_spec(mode=mode if mode is not None else "exact",
                              bits=bits if bits is not None else 8,
                              use_kernel=bool(use_kernel))
