"""MAC decoder: comparator bank -> thermometer code -> digital MAC count.

The paper's decoder (Fig 3/4) uses one comparator per MAC level; thresholds sit
between adjacent RBL levels.  Comparator i outputs 1 while V_RBL is ABOVE its
threshold, so count k produces the thermometer codes of Table I
(k=0 -> 11111111, k=8 -> 00000000) and ``count = rows - popcount(code)``.

``comparator_offset_sigma`` models input-referred comparator offset (the paper
notes 100-250 mV level spacing >> comparator noise; we expose it for
sensitivity studies and Monte-Carlo).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import constants as C
from repro.core.rbl import level_voltages


def thresholds(rows: int = C.ROWS, *, mode: str = "lut",
               t_eval: float = C.T_EVAL_S):
    """Comparator references: midpoints between adjacent count levels.

    Returned descending: thr[i] separates count i (above) from i+1 (below).
    """
    lv = level_voltages(rows, mode=mode, t_eval=t_eval)
    return 0.5 * (lv[:-1] + lv[1:])


def thermometer_code(v_rbl, *, rows: int = C.ROWS, mode: str = "lut",
                     t_eval: float = C.T_EVAL_S, comparator_offset_sigma=None,
                     key=None):
    """Comparator bank output: uint8 bits, bit i = (V_RBL > thr[i]).

    Shape: v_rbl.shape + (rows,).
    """
    thr = thresholds(rows, mode=mode, t_eval=t_eval)
    v = jnp.asarray(v_rbl, jnp.float32)[..., None]
    if comparator_offset_sigma is not None:
        if key is None:
            raise ValueError("comparator noise requires a PRNG key")
        thr = thr + comparator_offset_sigma * jax.random.normal(
            key, v.shape[:-1] + (rows,), jnp.float32)
    return (v > thr).astype(jnp.uint8)


def code_to_count(code):
    """Thermometer code -> MAC count: rows - popcount(code)."""
    code = jnp.asarray(code)
    return code.shape[-1] - jnp.sum(code.astype(jnp.int32), axis=-1)


def decode_voltage(v_rbl, *, rows: int = C.ROWS, mode: str = "lut",
                   t_eval: float = C.T_EVAL_S, comparator_offset_sigma=None,
                   key=None):
    """Full analog-to-digital decode: V_RBL -> MAC count (int32)."""
    code = thermometer_code(v_rbl, rows=rows, mode=mode, t_eval=t_eval,
                            comparator_offset_sigma=comparator_offset_sigma,
                            key=key)
    return code_to_count(code)
