"""High-level IMC matmul: quantize -> bit-serial MAC on the fabric -> dequant.

This is the paper's technique packaged as a drop-in GEMM:

  * mode="exact"  — digital equivalent of the IMC fabric (decode is exact for
                    every group, so group sums telescope): an int8 x int8
                    integer matmul with per-channel dequant.  This is the fast
                    path; on TPU it runs as a Pallas MXU kernel
                    (:mod:`repro.kernels.imc_mac`).
  * mode="sim"    — hardware-faithful emulation: offset-binary bit-planes,
                    per-8-row-group charge-sharing voltage, comparator
                    thermometer decode, optional device mismatch + comparator
                    offset noise.  Runs on the plane-batched engine
                    (:mod:`repro.core.bitserial`); with ``use_kernel=True``
                    the noise-free pyramid is ONE fused Pallas launch
                    (:mod:`repro.kernels.bitplane_mac` — all plane pairs x
                    K-groups x RBL voltage x comparator decode x weighted
                    accumulate).  Noisy sims (PRNG-keyed mismatch/comparator
                    offset) stay on the plane-batched jnp path, which folds
                    the key per plane pair inside the batch.

Both return float outputs plus an optional hardware cost report
(:class:`repro.core.energy.FabricReport`).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import constants as C
from repro.core.bitserial import bitserial_matmul_unsigned
from repro.core.energy import FabricReport, fabric_matmul_cost
from repro.core.quant import Quantized, quantize, signed_product_correction, to_offset_binary


def int_matmul(qa, qw):
    """int8 x int8 -> int32 matmul (MXU-native on TPU)."""
    return jax.lax.dot_general(
        qa.astype(jnp.int8), qw.astype(jnp.int8),
        (((qa.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


@partial(jax.jit, static_argnames=("bits", "mode", "rows", "mismatch",
                                   "use_kernel"))
def imc_matmul(x, w, *, bits: int = 8, mode: str = "exact",
               rows: int = C.ROWS, key=None, mismatch: bool = False,
               comparator_offset_sigma=None, use_kernel: bool = False):
    """IMC GEMM: y[..., N] ~= x[..., K] @ w[K, N] through the 8T SRAM fabric.

    Activations are quantized per-tensor (dynamic), weights per-output-channel.
    """
    qx = quantize(x, bits, axis=None)
    qw = quantize(w, bits, axis=0)  # per-column (output channel) scales
    if mode == "exact":
        if use_kernel:
            from repro.kernels.imc_mac.ops import imc_mac

            acc = imc_mac(qx.q, qw.q)
        else:
            acc = int_matmul(qx.q, qw.q)
    elif mode == "sim":
        u_a = to_offset_binary(qx.q, bits)
        u_w = to_offset_binary(qw.q, bits)
        noisy = mismatch or comparator_offset_sigma is not None
        if use_kernel and not noisy:
            from repro.kernels.bitplane_mac.ops import bitplane_mac

            uu = bitplane_mac(u_a, u_w, bits_a=bits, bits_w=bits, rows=rows)
        else:
            uu = bitserial_matmul_unsigned(
                u_a, u_w, bits_a=bits, bits_w=bits, rows=rows, mode="sim",
                key=key, mismatch=mismatch,
                comparator_offset_sigma=comparator_offset_sigma)
        acc = uu - signed_product_correction(u_a, u_w, bits)
    else:
        raise ValueError(mode)
    return acc.astype(jnp.float32) * qx.scale * qw.scale.reshape(
        (1,) * (acc.ndim - 1) + (-1,))


def imc_matmul_cost(x_shape, w_shape, *, bits: int = 8, rows: int = C.ROWS,
                    cols: int = C.COLS, n_macros: int = 1,
                    schedule: str = "weight_stationary") -> FabricReport:
    """Hardware cost projection for an imc_matmul call (energy/latency model)."""
    *batch, k = x_shape
    m = 1
    for b in batch:
        m *= b
    n = w_shape[-1]
    return fabric_matmul_cost(m, k, n, bits_a=bits, bits_w=bits, rows=rows,
                              cols=cols, n_macros=n_macros, schedule=schedule)


def quantize_weight(w, bits: int = 8) -> Quantized:
    """Static (load-time) weight quantization for ImcLinear."""
    return quantize(w, bits, axis=0)
