"""IMC matmul entry point — a thin spec-typed wrapper over the Fabric.

The real implementation lives in :mod:`repro.core.fabric`: a frozen, hashable
:class:`~repro.core.fabric.FabricSpec` names the precision/geometry/fidelity/
backend/noise of the fabric, and :func:`~repro.core.fabric.fabric_matmul`
dispatches it through the backend registry (exact int GEMM, plane-batched sim
engine, or the fused Pallas kernels), with the spec as the ONE static jit
argument:

    from repro.core.fabric import FabricSpec
    y = imc_matmul(x, w, FabricSpec(mode="sim", backend="pallas"))

The pre-spec loose kwargs (``bits=``, ``mode=``, ``use_kernel=`` ...) were
deprecated for one release and are now gone; passing them raises ``TypeError``
like any unknown keyword.
"""
from __future__ import annotations

from repro.core import constants as C
from repro.core.energy import FabricReport, fabric_matmul_cost
from repro.core.fabric import Fabric, FabricSpec, fabric_matmul, int_matmul
from repro.core.quant import Quantized, quantize

__all__ = ["imc_matmul", "imc_matmul_cost", "quantize_weight", "int_matmul"]


def imc_matmul(x, w, spec: FabricSpec | None = None, *, key=None):
    """IMC GEMM: y[..., N] ~= x[..., K] @ w[K, N] through the 8T SRAM fabric.

    ``spec`` defaults to the exact digital-equivalent fabric; ``key`` is
    required iff ``spec.noisy``.
    """
    return fabric_matmul(x, w, spec if spec is not None else FabricSpec(),
                         key=key)


def imc_matmul_cost(x_shape, w_shape, *, spec: FabricSpec | None = None,
                    bits: int = 8, rows: int = C.ROWS, cols: int = C.COLS,
                    n_macros: int = 1,
                    schedule: str = "weight_stationary") -> FabricReport:
    """Hardware cost projection for an imc_matmul call (energy/latency model).

    With ``spec`` given, delegates to :meth:`Fabric.cost`; the loose
    ``bits``/``rows``/``cols`` kwargs remain for cost-model sweeps that have
    no fabric in hand.
    """
    if spec is not None:
        return Fabric(spec).cost(x_shape, w_shape, n_macros=n_macros,
                                 schedule=schedule)
    *batch, k = x_shape
    m = 1
    for b in batch:
        m *= b
    n = w_shape[-1]
    return fabric_matmul_cost(m, k, n, bits_a=bits, bits_w=bits, rows=rows,
                              cols=cols, n_macros=n_macros, schedule=schedule)


def quantize_weight(w, bits: int = 8) -> Quantized:
    """Static (load-time) weight quantization for ImcLinear."""
    return quantize(w, bits, axis=0)
