"""Legacy-compatible IMC matmul entry point — now a thin shim over the Fabric.

The real implementation lives in :mod:`repro.core.fabric`: a frozen, hashable
:class:`~repro.core.fabric.FabricSpec` names the precision/geometry/fidelity/
backend/noise of the fabric, and :func:`~repro.core.fabric.fabric_matmul`
dispatches it through the backend registry (exact int GEMM, plane-batched sim
engine, or the fused Pallas kernels), with the spec as the ONE static jit
argument.

This module keeps the original loose-kwarg surface alive for one release:

    imc_matmul(x, w, bits=8, mode="sim", use_kernel=True)   # DeprecationWarning

maps onto the equivalent spec (including the old silent noisy-kernel -> jnp
fallback) and produces bit-identical results.  New code should write

    from repro.core.fabric import Fabric, FabricSpec
    y = Fabric(FabricSpec(mode="sim", backend="pallas")).matmul(x, w)

or pass a spec directly: ``imc_matmul(x, w, spec)``.
"""
from __future__ import annotations

from repro.core import constants as C
from repro.core.energy import FabricReport, fabric_matmul_cost
from repro.core.fabric import Fabric, FabricSpec, fabric_matmul, int_matmul
from repro.core.legacy import legacy_fabric_spec, warn_deprecated_kwargs
from repro.core.quant import Quantized, quantize


def imc_matmul(x, w, spec: FabricSpec | None = None, *, key=None,
               bits: int | None = None, mode: str | None = None,
               rows: int | None = None, mismatch: bool | None = None,
               comparator_offset_sigma=None, use_kernel: bool | None = None):
    """IMC GEMM: y[..., N] ~= x[..., K] @ w[K, N] through the 8T SRAM fabric.

    Prefer ``imc_matmul(x, w, spec, key=...)``.  The pre-spec kwargs
    (``bits``/``mode``/``rows``/``mismatch``/``comparator_offset_sigma``/
    ``use_kernel``) still work with a DeprecationWarning and identical
    semantics.
    """
    legacy = {k: v for k, v in dict(
        bits=bits, mode=mode, rows=rows, mismatch=mismatch,
        comparator_offset_sigma=comparator_offset_sigma,
        use_kernel=use_kernel).items() if v is not None}
    if legacy:
        if spec is not None:
            raise TypeError(
                f"pass either spec= or the legacy kwargs {sorted(legacy)}, "
                "not both")
        warn_deprecated_kwargs("imc_matmul", legacy)
        spec = legacy_fabric_spec(
            mode=mode if mode is not None else "exact",
            bits=bits if bits is not None else 8,
            rows=rows if rows is not None else C.ROWS,
            use_kernel=bool(use_kernel), mismatch=bool(mismatch),
            comparator_offset_sigma=comparator_offset_sigma)
    elif spec is None:
        spec = FabricSpec()
    return fabric_matmul(x, w, spec, key=key)


def imc_matmul_cost(x_shape, w_shape, *, spec: FabricSpec | None = None,
                    bits: int = 8, rows: int = C.ROWS, cols: int = C.COLS,
                    n_macros: int = 1,
                    schedule: str = "weight_stationary") -> FabricReport:
    """Hardware cost projection for an imc_matmul call (energy/latency model).

    With ``spec`` given, delegates to :meth:`Fabric.cost`; the loose
    ``bits``/``rows``/``cols`` kwargs remain for compatibility.
    """
    if spec is not None:
        return Fabric(spec).cost(x_shape, w_shape, n_macros=n_macros,
                                 schedule=schedule)
    *batch, k = x_shape
    m = 1
    for b in batch:
        m *= b
    n = w_shape[-1]
    return fabric_matmul_cost(m, k, n, bits_a=bits, bits_w=bits, rows=rows,
                              cols=cols, n_macros=n_macros, schedule=schedule)


def quantize_weight(w, bits: int = 8) -> Quantized:
    """Static (load-time) weight quantization for ImcLinear."""
    return quantize(w, bits, axis=0)
