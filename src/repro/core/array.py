"""Behavioral model of the 8x8 (RxC) 8T SRAM IMC array.

Functional-state design: the array contents are a plain ``uint8[rows, cols]``
jnp array (node Q of each cell); all operations are pure functions, so the
model is jit/vmap/scan friendly and batches across a "sea of macros".

Operations mirror the paper's peripheral circuitry:
  * ``write_row``   — write driver + row decoder (one row per write cycle)
  * ``read_bit``    — normal memory read through the decoupled read port
                      (single RWL active; count in {0,1} IS the stored bit —
                      no read disturbance, the 8T advantage)
  * ``mac``         — multi-row evaluation: pre-charge, assert RWL pattern,
                      charge-share, comparator decode (full analog path)
  * ``logic2``      — two-row evaluation interpreted as AND/OR/XOR/... per
                      column (8 columns -> bitwise 8-bit logic, Table II)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import constants as C
from repro.core.decoder import code_to_count, thermometer_code
from repro.core.energy import mac_energy_fj
from repro.core.logic import logic_from_count
from repro.core.rbl import rbl_voltage


@dataclass(frozen=True)
class ArraySpec:
    rows: int = C.ROWS
    cols: int = C.COLS
    mode: str = "lut"  # "lut" (canonical 8x8) | "physics" (any geometry)
    t_eval: float = C.T_EVAL_S

    def __post_init__(self):
        if self.mode == "lut" and self.rows != C.ROWS:
            raise ValueError("lut mode requires 8 rows")


class MacResult(NamedTuple):
    counts: jnp.ndarray  # int32[cols]   decoded MAC counts
    volts: jnp.ndarray  # float32[cols] analog RBL voltages
    codes: jnp.ndarray  # uint8[cols, rows] thermometer codes
    energy_fj: jnp.ndarray  # float32[cols] per-column RBL energy (Table III model)


def empty_state(spec: ArraySpec = ArraySpec()):
    return jnp.zeros((spec.rows, spec.cols), jnp.uint8)


def write_row(state, row, bits):
    """One write cycle: drive BL/BLbar on ``row`` with ``bits`` (uint8[cols])."""
    return state.at[row].set(jnp.asarray(bits, jnp.uint8))


def write(state, bits):
    """Load a full operand matrix (rows x cols) over ``rows`` write cycles."""
    return jnp.asarray(bits, jnp.uint8).reshape(state.shape)


def mac(state, rwl, spec: ArraySpec = ArraySpec(), *, k_noise=None,
        comparator_offset_sigma=None, key=None) -> MacResult:
    """Full analog MAC path for one evaluation.

    ``rwl``: uint8[rows] word-line activation pattern (operand A bits).
    ``k_noise``: optional float[cols] additive mismatch on the effective count
    (from :mod:`repro.core.montecarlo`).
    """
    rwl = jnp.asarray(rwl, jnp.int32)
    k = rwl @ state.astype(jnp.int32)  # int[cols]: true MAC counts
    k_eff = k.astype(jnp.float32)
    if k_noise is not None:
        k_eff = k_eff + k_noise
    v = rbl_voltage(k_eff, rows=spec.rows, t_eval=spec.t_eval, mode=spec.mode)
    codes = thermometer_code(v, rows=spec.rows, mode=spec.mode,
                             t_eval=spec.t_eval,
                             comparator_offset_sigma=comparator_offset_sigma,
                             key=key)
    counts = code_to_count(codes)
    return MacResult(counts, v, codes, mac_energy_fj(counts))


def read_bit(state, row, spec: ArraySpec = ArraySpec()):
    """Normal SRAM read via the read port: count of a single-RWL evaluation."""
    rwl = jnp.zeros((spec.rows,), jnp.uint8).at[row].set(1)
    return mac(state, rwl, spec).counts.astype(jnp.uint8)


def logic2(state, row_a, row_b, spec: ArraySpec = ArraySpec(), **noise):
    """Two-row evaluation -> all MAC-derived logic ops, bitwise per column.

    Returns (dict op->uint8[cols], MacResult).
    """
    rwl = jnp.zeros((spec.rows,), jnp.uint8).at[row_a].set(1).at[row_b].set(1)
    res = mac(state, rwl, spec, **noise)
    return logic_from_count(res.counts, m=2), res
