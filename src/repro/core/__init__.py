"""Core library: the paper's 8T SRAM IMC architecture, TPU-adapted.

Layers (bottom-up):
  constants   — paper tables + calibrated fit constants + TPU targets
  rbl         — charge-sharing RBL discharge model (LUT + physics fit)
  decoder     — comparator bank / thermometer decode
  logic       — MAC-derived AND/NAND, OR/NOR, XOR/XNOR, 1-bit ADD
  array       — behavioral RxC macro (write/read/mac/logic2)
  energy      — energy/latency/throughput + fabric projection model
  montecarlo  — device-mismatch MC (Fig 6)
  quant       — int8 symmetric quant + offset-binary bit-planes
  bitserial   — grouped bit-plane MAC with analog decode in the loop
  fabric      — FabricSpec/NoiseSpec + Fabric facade + backend registry:
                the ONE typed, hashable entry point to the stack
  imc_matmul  — spec-typed entry point over fabric_matmul (+ cost sweeps)
  imc_linear  — drop-in Linear on the IMC fabric (STE backward)
"""
from repro.core import constants
from repro.core.array import ArraySpec, MacResult, empty_state, logic2, mac, read_bit, write, write_row
from repro.core.decoder import code_to_count, decode_voltage, thermometer_code, thresholds
from repro.core.energy import FabricReport, Timing, fabric_matmul_cost, logic_energy_fj, mac_energy_fj
from repro.core.fabric import Fabric, FabricSpec, NoiseSpec, fabric_matmul
from repro.core.imc_linear import apply_imc_linear, imc_linear_apply, init_imc_linear
from repro.core.imc_matmul import imc_matmul, imc_matmul_cost
from repro.core.logic import add_1bit, logic_from_count
from repro.core.montecarlo import mc_energy_fj, mc_stats
from repro.core.rbl import level_voltages, rbl_voltage

__all__ = [
    "constants", "ArraySpec", "MacResult", "empty_state", "write", "write_row",
    "read_bit", "mac", "logic2", "thresholds", "thermometer_code",
    "code_to_count", "decode_voltage", "logic_from_count", "add_1bit",
    "mac_energy_fj", "logic_energy_fj", "Timing", "fabric_matmul_cost",
    "mc_energy_fj", "mc_stats", "rbl_voltage", "level_voltages",
    "Fabric", "FabricSpec", "NoiseSpec", "FabricReport", "fabric_matmul",
    "imc_matmul", "imc_matmul_cost", "init_imc_linear", "apply_imc_linear",
    "imc_linear_apply",
]
