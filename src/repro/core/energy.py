"""Energy / latency / throughput model of the IMC macro and fabric.

Calibrated to the paper:
  * Table III  — per-evaluation RBL energy vs MAC count (LUT, exact), plus a
                 quadratic-in-dV fit (<=0.31 fJ abs residual) for fractional /
                 extrapolated counts.
  * Table IV   — 1-bit logic energies (== E(count) of the producing MAC).
  * Fig 5      — 7 ns cycle; 8 write + 1 precharge/eval cycles = 63 ns per
                 cold operation; 0.7 ns evaluation window; 15.8 Mops/s.

The fabric model projects a full (M,K,N) bit-plane matmul onto a sea of RxC
macros — the paper's §III-F scalability argument made quantitative.  Two
scheduling modes:
  * ``cold``              — every evaluation pays the full 9-cycle op (paper's
                            reported throughput number)
  * ``weight_stationary`` — operand B loaded once, then one precharge+eval
                            cycle per evaluation (the natural DNN mapping)
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import constants as C


# ------------------------------------------------------------------ energy
def mac_energy_fj(count, *, exact: bool = True):
    """RBL energy (fJ) of one evaluation with MAC count ``count``.

    ``exact=True`` uses the Table III LUT (integer counts, linear interp for
    fractional); ``exact=False`` uses the quadratic dV fit (any geometry).
    """
    count = jnp.asarray(count)
    if exact:
        k = jnp.clip(count.astype(jnp.float32), 0.0, float(C.ROWS))
        lut = jnp.asarray(C.E_MAC_TABLE_FJ, jnp.float32)
        lo = jnp.clip(jnp.floor(k).astype(jnp.int32), 0, C.ROWS - 1)
        frac = k - lo.astype(jnp.float32)
        return lut[lo] * (1.0 - frac) + lut[lo + 1] * frac
    from repro.core.rbl import rbl_voltage_physics

    dv = C.VDD - rbl_voltage_physics(count)
    return C.E_FIT_E0 + C.E_FIT_A * dv + C.E_FIT_B * dv * dv


def energy_from_voltage_fj(v_rbl):
    """Quadratic fit E(dV) — usable straight from an analog voltage."""
    dv = C.VDD - jnp.asarray(v_rbl, jnp.float32)
    return C.E_FIT_E0 + C.E_FIT_A * dv + C.E_FIT_B * dv * dv


def logic_energy_fj(op: str) -> float:
    """Table IV: energy of a 1-bit logic op (it IS a 2-row MAC evaluation)."""
    key = op.upper()
    if key in C.E_LOGIC_FJ:
        return C.E_LOGIC_FJ[key]
    # Remaining ops share their complement's evaluation (same MAC count).
    alias = {"NAND": "AND", "OR": "NOR", "XNOR": "XOR"}
    return C.E_LOGIC_FJ[alias[key]]


# ------------------------------------------------------------------ timing
@dataclass(frozen=True)
class Timing:
    t_cycle_s: float = C.T_CYCLE_S
    n_write_cycles: int = C.N_WRITE_CYCLES
    n_pre_eval_cycles: int = C.N_PRE_EVAL_CYCLES

    @property
    def t_op_s(self) -> float:  # complete cold operation (Fig 5): 63 ns
        return (self.n_write_cycles + self.n_pre_eval_cycles) * self.t_cycle_s

    @property
    def throughput_ops(self) -> float:  # ~15.87 Mops/s
        return 1.0 / self.t_op_s

    @property
    def f_clk_hz(self) -> float:
        return 1.0 / self.t_cycle_s

    @property
    def t_eval_s(self) -> float:  # MAC latency (paper: 0.7 ns)
        return C.T_EVAL_S


# ------------------------------------------------------------------ fabric
@dataclass(frozen=True)
class FabricReport:
    evaluations: int  # total macro evaluations
    array_ops: int  # macro-op slots (each yields `cols` results)
    weight_load_cycles: int
    latency_s: float
    energy_j: float
    energy_fj_per_mac: float
    macs: int  # useful 1-bit MACs performed
    tops_per_w: float  # 1-bit-MAC ops/s/W equivalent


def fabric_matmul_cost(m: int, k: int, n: int, *, bits_a: int = 8,
                       bits_w: int = 8, rows: int = C.ROWS,
                       cols: int = C.COLS, n_macros: int = 1,
                       schedule: str = "weight_stationary",
                       mean_count: float | None = None) -> FabricReport:
    """Project an (M,K) x (K,N) bit-plane matmul onto a fabric of macros.

    One evaluation processes one (m-row-index, k-group, weight-plane,
    activation-plane) against ``cols`` output columns.  ``mean_count`` is the
    expected MAC count per evaluation (defaults to the random-bit expectation
    rows/4, i.e. bit-density 1/2 on both operands).
    """
    groups = -(-k // rows)
    col_tiles = -(-n // cols)
    evaluations = m * groups * bits_a * bits_w * col_tiles
    weight_loads = groups * bits_w * col_tiles * rows  # write cycles
    timing = Timing()
    if schedule == "cold":
        t_per_eval = timing.t_op_s
        load_cycles = evaluations * timing.n_write_cycles
    elif schedule == "weight_stationary":
        t_per_eval = timing.n_pre_eval_cycles * timing.t_cycle_s
        load_cycles = weight_loads
    else:
        raise ValueError(schedule)
    latency = (evaluations * t_per_eval + load_cycles * timing.t_cycle_s *
               (0 if schedule == "cold" else 1)) / max(n_macros, 1)
    if mean_count is None:
        mean_count = rows / 4.0  # E[sum of 8 Bernoulli(1/4)] for random bits
    e_eval_fj = float(np.asarray(mac_energy_fj(jnp.float32(mean_count))))
    energy_j = evaluations * cols * e_eval_fj * 1e-15
    macs = m * k * n * bits_a * bits_w  # 1-bit MAC equivalents
    power_w = energy_j / latency if latency > 0 else float("inf")
    tops_w = (macs / latency) / power_w / 1e12 if power_w > 0 else 0.0
    return FabricReport(evaluations, evaluations, weight_loads, latency,
                        energy_j, e_eval_fj, macs, tops_w)
