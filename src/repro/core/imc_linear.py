"""ImcLinear — a Linear layer executed on the (modeled) IMC fabric.

Drop-in replacement for a dense projection inside the model zoo, configured by
ONE :class:`~repro.core.fabric.FabricSpec`.  Forward: dynamic activation quant
at ``bits_a`` + static-scale weights at ``bits_w`` + the spec's fabric engine
(exact int GEMM / plane-batched sim / fused Pallas kernel, with optional
PRNG-keyed noise), dequant, optional bias.

Backward: straight-through estimator — gradients flow as if the layer were the
underlying float matmul (standard QAT practice), so the same module is usable
in training AND serving.  The spec is the custom_vjp's ONLY nondiff argument
(it is hashable, so it jit-caches like any static); the noise key rides as a
regular primal with a ``None`` cotangent.

The pre-spec positional signature ``imc_linear_apply(x, w, b, bits, mode,
use_kernel)`` and the matching loose kwargs finished their deprecation cycle
and are gone; the spec is the only configuration channel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.fabric import FabricSpec, fabric_matmul


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def _imc_linear(x, w, b, key, spec: FabricSpec):
    y = fabric_matmul(x, w, spec, key=key)
    if b is not None:
        y = y + b
    return y


def _fwd(x, w, b, key, spec):
    return _imc_linear(x, w, b, key, spec), (x, w, b is None)


def _bwd(spec, res, g):
    x, w, no_bias = res
    g = g.astype(jnp.float32)
    dx = jnp.einsum("...n,kn->...k", g, w.astype(jnp.float32)).astype(x.dtype)
    dw = jnp.einsum("...k,...n->kn",
                    x.reshape(-1, x.shape[-1]).astype(jnp.float32),
                    g.reshape(-1, g.shape[-1])).astype(w.dtype)
    db = None if no_bias else jnp.sum(
        g.reshape(-1, g.shape[-1]), axis=0).astype(g.dtype)
    return dx, dw, db, None  # the PRNG key has no cotangent


_imc_linear.defvjp(_fwd, _bwd)


def imc_linear_apply(x, w, b=None, *, spec: FabricSpec | None = None,
                     key=None):
    """y = fabric(x @ w) + b with STE backward, configured by ``spec``.

    ``key`` is required iff ``spec.noisy`` and threads down to the bit-serial
    engine's per-plane-pair PRNG folds.
    """
    return _imc_linear(x, w, b, key, spec if spec is not None else FabricSpec())


def init_imc_linear(key, d_in: int, d_out: int, *, use_bias: bool = False,
                    dtype=jnp.float32, scale: float | None = None):
    """He-style init; params pytree compatible with models/ layers."""
    s = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)
    p = {"w": w}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_imc_linear(params, x, *, spec: FabricSpec | None = None, key=None):
    return imc_linear_apply(x, params["w"], params.get("b"), spec=spec,
                            key=key)
