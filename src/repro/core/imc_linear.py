"""ImcLinear — a Linear layer executed on the (modeled) IMC fabric.

Drop-in replacement for a dense projection inside the model zoo.  Forward:
dynamic int8 activation quant + static-scale int8 weights + integer GEMM
(exact IMC-equivalent path; Pallas kernel on TPU), dequant, optional bias.

Backward: straight-through estimator — gradients flow as if the layer were the
underlying float matmul (standard QAT practice), so the same module is usable
in training AND serving.  ``mode="sim"`` additionally pushes the forward
through the analog decode path (group-wise, with optional noise) for
hardware-in-the-loop robustness studies; ``mode="sim", use_kernel=True``
runs the whole bit-plane pyramid as one fused Pallas launch
(:mod:`repro.kernels.bitplane_mac`) instead of 64 einsum+decode rounds.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.imc_matmul import imc_matmul


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def imc_linear_apply(x, w, b, bits: int = 8, mode: str = "exact",
                     use_kernel: bool = False):
    y = imc_matmul(x, w, bits=bits, mode=mode, use_kernel=use_kernel)
    if b is not None:
        y = y + b
    return y


def _fwd(x, w, b, bits, mode, use_kernel):
    return imc_linear_apply(x, w, b, bits, mode, use_kernel), (x, w, b is None)


def _bwd(bits, mode, use_kernel, res, g):
    x, w, no_bias = res
    g = g.astype(jnp.float32)
    dx = jnp.einsum("...n,kn->...k", g, w).astype(x.dtype)
    dw = jnp.einsum("...k,...n->kn",
                    x.reshape(-1, x.shape[-1]).astype(jnp.float32),
                    g.reshape(-1, g.shape[-1])).astype(w.dtype)
    db = None if no_bias else jnp.sum(
        g.reshape(-1, g.shape[-1]), axis=0).astype(g.dtype)
    return dx, dw, db


imc_linear_apply.defvjp(_fwd, _bwd)


def init_imc_linear(key, d_in: int, d_out: int, *, use_bias: bool = False,
                    dtype=jnp.float32, scale: float | None = None):
    """He-style init; params pytree compatible with models/ layers."""
    s = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)
    p = {"w": w}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_imc_linear(params, x, *, bits: int = 8, mode: str = "exact",
                     use_kernel: bool = False):
    b = params.get("b")
    return imc_linear_apply(x, params["w"], b, bits, mode, use_kernel)
