"""Paper-calibrated constants for the 8T SRAM IMC architecture.

All table values are transcribed from the paper (90 nm CMOS, 1.8 V):
  Table I   — RBL voltage vs MAC count (8 rows, C_RBL = 200 fF, t_eval = 0.7 ns)
  Table III — RBL energy vs MAC count (fJ per 8-operand MAC evaluation)
  Table IV  — 1-bit logic energies (fJ)
  Fig 5     — timing: 7 ns cycle (142.85 MHz), 8 write cycles + precharge +
              0.7 ns evaluation window = 63 ns per complete operation
  Fig 6     — Monte-Carlo (k=8, 200 samples): mean 437 fJ, sigma 48.72 fJ

Physics-fit constants (two-regime discharge, fitted offline to Table I,
rmse 12.4 mV) let the model extrapolate to row counts != 8 (paper §III-F).
"""
from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------- paper tables
ROWS = 8
COLS = 8
VDD = 1.8  # V supply / RBL pre-charge
C_RBL = 200e-15  # F, RBL load capacitance (paper §IV-A)

# Table I: RBL voltage (V) for MAC count k = 0..8.
V_RBL_TABLE = np.array(
    [1.758, 1.528, 1.308, 1.096, 0.895, 0.712, 0.552, 0.418, 0.310]
)

# Table III: RBL energy (fJ) for 8-operand MAC with count k = 0..8.
E_MAC_TABLE_FJ = np.array(
    [5.369, 119.3, 212.7, 288.5, 347.9, 391.6, 421.5, 440.7, 452.2]
)

# Table IV: 1-bit logic energies (fJ) == E(k) of the MAC count each op produces.
E_LOGIC_FJ = {"AND": 212.7, "CARRY": 212.7, "NOR": 5.369, "XOR": 119.3, "SUM": 119.3}

# Fig 5 timing model.
F_CLK_HZ = 142.85e6
T_CYCLE_S = 7e-9  # 1 / 142.85 MHz
T_EVAL_S = 0.7e-9  # RWL activation (evaluation) window
N_WRITE_CYCLES = 8  # operand-B load
N_PRE_EVAL_CYCLES = 1  # pre-charge + evaluate
T_OP_S = (N_WRITE_CYCLES + N_PRE_EVAL_CYCLES) * T_CYCLE_S  # 63 ns
THROUGHPUT_OPS = 1.0 / T_OP_S  # ~15.87 M ops/s (paper: 15.8)
ENERGY_PER_BIT_FJ = E_MAC_TABLE_FJ[-1] / 8.0  # 56.5 fJ/bit (paper: 56.56)

# Fig 6 Monte-Carlo statistics at k = 8.
MC_MEAN_FJ = 437.0
MC_STD_FJ = 48.72
MC_SAMPLES = 200

# ------------------------------------------------- physics fit (dev-calibrated)
# Two-regime discharge: per-active-cell linear drop U_LIN while V > VD_SAT
# (velocity-saturated read stack), exponential (triode / RC) below.
V0_LEAK = float(V_RBL_TABLE[0])  # 1.758 V: pre-charge minus leakage droop
U_LIN = 0.216845  # V of linear drop per active cell per 0.7 ns window
VD_SAT = 0.865014  # V, regime boundary

# Energy fit E(dV) = E0 + A*dV + B*dV^2 with dV = VDD - V_RBL (fJ; dev-fitted,
# max abs residual 0.31 fJ against Table III).
E_FIT_E0 = -16.744077
E_FIT_A = 540.201964
E_FIT_B = -151.403517

# Monte-Carlo mismatch calibration: E = E(0) + sum_i g_i * dE_i with
# dE_i = E(i) - E(i-1) (per-discharge-path charge increments) and
# g_i ~ N(MU_G, SIGMA_G). Closed form:
#   std  = SIGMA_G * sqrt(sum dE_i^2)          -> SIGMA_G from paper sigma
#   mean = E(0) + MU_G * sum dE_i              -> MU_G from paper mean
_DE = np.diff(E_MAC_TABLE_FJ)
MC_SIGMA_G = MC_STD_FJ / float(np.sqrt(np.sum(_DE**2)))
MC_MU_G = (MC_MEAN_FJ - float(E_MAC_TABLE_FJ[0])) / float(np.sum(_DE))

# Voltage-referred mismatch, expressed as count-equivalent noise per active
# path.  Distinct from the (much larger) energy-referred spread: the paper
# states level ordering and 100-250 mV spacing are preserved across mismatch
# and corners (§III-F, §IV-C), i.e. decode errors are rare.  0.05 counts/path
# ~= 10 mV at the 200 mV level spacing — consistent with that claim while
# still letting robustness studies flip marginal codes occasionally.
MC_SIGMA_VK = 0.05

# ------------------------------------------------------------- TPU v5e targets
TPU_PEAK_FLOPS_BF16 = 197e12  # per chip
TPU_HBM_BW = 819e9  # B/s per chip
TPU_ICI_BW = 50e9  # B/s per link
