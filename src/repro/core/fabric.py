"""FabricSpec + Fabric: the single typed entry point to the whole IMC stack.

The paper's 8T SRAM macro exposes MAC, logic, and memory modes through ONE
array interface; this module is the software mirror of that device descriptor.
A :class:`FabricSpec` is a frozen, hashable value object that fully determines
how a GEMM (or logic op) executes on the modeled fabric:

  * precision   — ``bits_a`` x ``bits_w`` (asymmetric supported end-to-end)
  * geometry    — ``rows`` x ``cols`` macro tiles
  * fidelity    — ``mode="exact"`` (digital-equivalent int GEMM) or
                  ``mode="sim"`` (offset-binary bit-planes, charge-sharing RBL
                  voltage, comparator thermometer decode)
  * engine      — ``backend="jnp" | "pallas" | "auto"`` (auto picks the fused
                  Pallas kernel on TPU, the plane-batched jnp engine elsewhere)
  * non-ideality— ``noise=NoiseSpec(...)`` (device mismatch on the effective
                  count, comparator offset), PRNG-keyed

Because the spec is hashable it rides ``jax.jit`` as a single static argument:
two calls with equal specs share one compiled executable, and the spec can be
embedded in model configs (:class:`repro.configs.base.ModelConfig.fabric`)
without breaking their hashability.

The :class:`Fabric` facade bundles the four things you do with a macro:

    fab = Fabric(FabricSpec(mode="sim", noise=NoiseSpec(mismatch_sigma=0.05)))
    y   = fab.matmul(x, w, key=key)          # quant -> fabric GEMM -> dequant
    y   = fab.linear(params, x, key=key)     # Linear layer, STE backward
    c   = fab.logic(a, b, "XOR")             # MAC-derived bitwise logic
    rep = fab.cost(x.shape, w.shape)         # energy/latency FabricReport

Backend resolution happens in a small registry keyed by
``(mode, backend, noisy)``; unsupported combinations raise immediately at
spec/facade construction instead of silently falling back.  Noisy sims are
first-class on BOTH engines: the jnp keyed path is the statistical oracle,
and ``backend="pallas"`` runs the whole noisy pyramid as one fused kernel
with in-kernel PRNG (``kernels/bitplane_mac``) — same key -> identical
outputs, cross-engine agreement pinned on moments/quantiles (different PRNG
streams make bit-identity impossible).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import constants as C
from repro.core.bitserial import bitserial_matmul_unsigned, decode_group_counts
from repro.core.energy import FabricReport, fabric_matmul_cost
from repro.core.logic import (OPS, add_nbit, logic_from_count, logic_word)
from repro.core.quant import quantize, signed_product_correction, to_offset_binary

MODES = ("exact", "sim")
BACKENDS = ("auto", "jnp", "pallas")


# ------------------------------------------------------------------- specs
@dataclass(frozen=True)
class NoiseSpec:
    """Analog non-idealities of the sim path (both optional, PRNG-keyed).

    mismatch_sigma          — voltage-referred device mismatch on the
                              effective MAC count (stddev per unit sqrt(count);
                              the paper-calibrated value is
                              ``constants.MC_SIGMA_VK``).
    comparator_offset_sigma — input-referred comparator offset (V) on the
                              thermometer decode references.
    """

    mismatch_sigma: Optional[float] = None
    comparator_offset_sigma: Optional[float] = None

    def __post_init__(self):
        for name in ("mismatch_sigma", "comparator_offset_sigma"):
            v = getattr(self, name)
            if v is not None and v < 0:
                raise ValueError(f"NoiseSpec.{name} must be >= 0, got {v}")

    @property
    def enabled(self) -> bool:
        return (self.mismatch_sigma is not None
                or self.comparator_offset_sigma is not None)

    @classmethod
    def calibrated(cls) -> "NoiseSpec":
        """Device mismatch at the paper-calibrated sigma (Fig 6 / §IV-C)."""
        return cls(mismatch_sigma=C.MC_SIGMA_VK)


@dataclass(frozen=True)
class FabricSpec:
    """Complete, hashable description of one IMC fabric configuration."""

    bits_a: int = 8
    bits_w: int = 8
    rows: int = C.ROWS
    cols: int = C.COLS
    mode: str = "exact"  # exact | sim
    backend: str = "auto"  # auto | jnp | pallas
    noise: Optional[NoiseSpec] = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}")
        for name in ("bits_a", "bits_w"):
            b = getattr(self, name)
            if not 2 <= b <= 8:
                raise ValueError(f"{name} must be in [2, 8] (int8 storage), "
                                 f"got {b}")
        if self.rows < 2 or self.cols < 1:
            raise ValueError(f"invalid geometry {self.rows}x{self.cols}")
        # Canonicalize an all-off NoiseSpec to None so equality/hashing (and
        # hence the jit cache) don't distinguish "no noise" spellings.
        if self.noise is not None and not self.noise.enabled:
            object.__setattr__(self, "noise", None)
        if self.noisy and self.mode != "sim":
            raise ValueError(
                "noise is only meaningful on the analog sim path; use "
                "mode='sim' (exact mode is the noise-free digital equivalent)")

    # -------------------------------------------------------------- derived
    @property
    def noisy(self) -> bool:
        return self.noise is not None

    @property
    def bits(self) -> int:
        """Symmetric precision accessor; raises when bits_a != bits_w."""
        if self.bits_a != self.bits_w:
            raise ValueError(
                f"spec has asymmetric precision {self.bits_a}x{self.bits_w}; "
                "use bits_a/bits_w explicitly")
        return self.bits_a

    def resolve_backend(self) -> str:
        """Concrete engine name: 'auto' -> pallas on TPU, jnp elsewhere."""
        if self.backend != "auto":
            return self.backend
        if jax.default_backend() == "tpu":
            return "pallas"
        return "jnp"

    @property
    def label(self) -> str:
        """Short row label for benches/logs: e.g. ``sim/jnp+noise``."""
        s = f"{self.mode}/{self.resolve_backend()}"
        return s + "+noise" if self.noisy else s

    def replace(self, **kw) -> "FabricSpec":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------- registry
# (mode, backend, noisy) -> engine(qa, qw, spec, key) -> int32 accumulator
# qa: int[..., K] signed quantized activations; qw: int[K, N] signed weights.
_ENGINES: Dict[Tuple[str, str, bool], Callable] = {}


def register_engine(mode: str, backend: str, noisy: bool):
    def deco(fn):
        _ENGINES[(mode, backend, noisy)] = fn
        return fn
    return deco


def resolve_engine(spec: FabricSpec) -> Callable:
    """Engine for a spec; raises (early, with the menu) on unsupported combos."""
    key = (spec.mode, spec.resolve_backend(), spec.noisy)
    try:
        return _ENGINES[key]
    except KeyError:
        combos = ", ".join(
            f"{m}/{b}{'+noise' if n else ''}" for m, b, n in sorted(_ENGINES))
        raise ValueError(
            f"no fabric engine for mode={key[0]!r} backend={key[1]!r} "
            f"noisy={key[2]}; supported: {combos}") from None


def int_matmul(qa, qw):
    """int8 x int8 -> int32 matmul (MXU-native on TPU)."""
    return jax.lax.dot_general(
        qa.astype(jnp.int8), qw.astype(jnp.int8),
        (((qa.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


@register_engine("exact", "jnp", False)
def _exact_jnp(qa, qw, spec, key):
    return int_matmul(qa, qw)


@register_engine("exact", "pallas", False)
def _exact_pallas(qa, qw, spec, key):
    from repro.kernels.imc_mac.ops import imc_mac

    return imc_mac(qa, qw)


def _sim_correction(qa, qw, spec):
    u_a = to_offset_binary(qa, spec.bits_a)
    u_w = to_offset_binary(qw, spec.bits_w)
    return u_a, u_w, signed_product_correction(u_a, u_w, spec.bits_a,
                                               spec.bits_w)


@register_engine("sim", "jnp", False)
def _sim_jnp(qa, qw, spec, key):
    u_a, u_w, corr = _sim_correction(qa, qw, spec)
    uu = bitserial_matmul_unsigned(u_a, u_w, bits_a=spec.bits_a,
                                   bits_w=spec.bits_w, rows=spec.rows,
                                   mode="sim")
    return uu - corr


@register_engine("sim", "jnp", True)
def _sim_jnp_noisy(qa, qw, spec, key):
    u_a, u_w, corr = _sim_correction(qa, qw, spec)
    uu = bitserial_matmul_unsigned(
        u_a, u_w, bits_a=spec.bits_a, bits_w=spec.bits_w, rows=spec.rows,
        mode="sim", key=key, mismatch_sigma=spec.noise.mismatch_sigma,
        comparator_offset_sigma=spec.noise.comparator_offset_sigma)
    return uu - corr


@register_engine("sim", "pallas", False)
def _sim_pallas(qa, qw, spec, key):
    from repro.kernels.bitplane_mac.ops import bitplane_mac

    u_a, u_w, corr = _sim_correction(qa, qw, spec)
    uu = bitplane_mac(u_a, u_w, bits_a=spec.bits_a, bits_w=spec.bits_w,
                      rows=spec.rows)
    return uu - corr


@register_engine("sim", "pallas", True)
def _sim_pallas_noisy(qa, qw, spec, key):
    from repro.kernels.bitplane_mac.ops import bitplane_mac_noisy

    u_a, u_w, corr = _sim_correction(qa, qw, spec)
    uu = bitplane_mac_noisy(
        u_a, u_w, key, bits_a=spec.bits_a, bits_w=spec.bits_w,
        rows=spec.rows, mismatch_sigma=spec.noise.mismatch_sigma,
        comparator_offset_sigma=spec.noise.comparator_offset_sigma)
    return uu - corr


# ------------------------------------------------------------------ matmul
@partial(jax.jit, static_argnames=("spec", "geom"))
def _fabric_matmul_jit(x, w, spec: FabricSpec, key, geom):
    del geom  # cache-key only: retrace when tuned kernel geometry changes
    engine = resolve_engine(spec)
    qx = quantize(x, spec.bits_a, axis=None)
    qw = quantize(w, spec.bits_w, axis=0)  # per-column (output channel)
    acc = engine(qx.q, qw.q, spec, key)
    return acc.astype(jnp.float32) * qx.scale * qw.scale.reshape(
        (1,) * (acc.ndim - 1) + (-1,))


def fabric_matmul(x, w, spec: FabricSpec = FabricSpec(), *, key=None):
    """y[..., N] ~= x[..., K] @ w[K, N] through the fabric described by spec.

    Activations quantize per-tensor (dynamic) at ``bits_a``; weights per
    output channel at ``bits_w``.  ``key`` is required iff ``spec.noisy``.

    Plain wrapper over one inner jit whose static arguments are the spec and
    the autotuner's :func:`~repro.kernels.autotune.geometry_token` — equal
    specs under an unchanged tuning state share one compiled executable, and
    a re-tune (or a ``REPRO_TUNE_*`` pin change) busts the cache instead of
    silently reusing stale tile geometry.
    """
    from repro.kernels import autotune

    if spec.noisy and key is None:
        raise ValueError(f"spec {spec.label} is noisy: pass key=")
    return _fabric_matmul_jit(x, w, spec, key, autotune.geometry_token())


# the recompile-detector tests watch the inner jit's cache through the wrapper
fabric_matmul._cache_size = _fabric_matmul_jit._cache_size


# ------------------------------------------------------------------ facade
class Fabric:
    """All four faces of the macro — GEMM, layer, logic, cost — on one spec."""

    def __init__(self, spec: FabricSpec = FabricSpec()):
        self.spec = spec
        self._engine = resolve_engine(spec)  # raise on bad combos up front

    def __repr__(self):
        return f"Fabric({self.spec!r})"

    def matmul(self, x, w, *, key=None):
        """Quantize -> fabric GEMM -> dequant.  See :func:`fabric_matmul`."""
        return fabric_matmul(x, w, self.spec, key=key)

    def linear(self, params, x, *, key=None):
        """Linear layer on the fabric: params {"w": (K,N)[, "b": (N,)]}.

        Straight-through estimator backward (gradients of the float matmul),
        so the same layer trains and serves.
        """
        from repro.core.imc_linear import imc_linear_apply

        return imc_linear_apply(x, params["w"], params.get("b"),
                                spec=self.spec, key=key)

    def _count_decode(self, key):
        """counts -> counts through the spec's decode path, fresh-keyed.

        Each call of the returned closure folds a new stream off ``key``, so
        multi-evaluation word ops (ripple-carry stages) draw independent
        noise per MAC activation — mirroring distinct array cycles.
        """
        if self.spec.noisy and key is None:
            raise ValueError(f"spec {self.spec.label} is noisy: pass key=")
        state = {"n": 0}

        def decode(count):
            kw = {}
            if self.spec.noisy:
                kw = dict(key=jax.random.fold_in(key, state["n"]),
                          mismatch_sigma=self.spec.noise.mismatch_sigma,
                          comparator_offset_sigma=(
                              self.spec.noise.comparator_offset_sigma))
                state["n"] += 1
            return decode_group_counts(count, mode=self.spec.mode,
                                       rows=self.spec.rows, **kw)

        return decode

    def logic(self, a, b, op: str, *, key=None):
        """MAC-derived bitwise logic (paper §III-B..E, Table II).

        ``a``, ``b``: {0,1} arrays (any shape, broadcastable).  The 2-operand
        MAC count goes through the spec's decode path (exact clip, or the
        analog voltage + comparator model for ``mode="sim"``, with the spec's
        noise when keyed), then the Boolean function is read off the count.
        """
        op = op.upper()
        if op not in OPS:
            raise ValueError(f"op must be one of {OPS}, got {op!r}")
        count = jnp.asarray(a, jnp.int32) + jnp.asarray(b, jnp.int32)
        dec = self._count_decode(key)(count)
        return logic_from_count(dec, m=2)[op]

    def logic_word(self, a, b, op: str, *, bits: int = 8, key=None):
        """Bitwise ``op`` over packed ``bits``-wide words (paper §III).

        8 columns evaluate in parallel per macro activation, so a uint8 word
        is one MAC cycle; every column's count runs through the spec's
        decode path (``key`` required iff noisy).
        """
        return logic_word(a, b, op, bits=bits, decode=self._count_decode(key))

    def add_nbit(self, a, b, *, bits: int = 8, key=None):
        """Ripple-carry word addition from 1-bit MAC adders (paper §III-E).

        Returns ``(sum mod 2**bits, carry_out)``; each half-adder stage is a
        separate keyed MAC evaluation under a noisy spec.
        """
        return add_nbit(a, b, bits=bits, decode=self._count_decode(key))

    def cost(self, x_shape, w_shape, *, n_macros: int = 1,
             schedule: str = "weight_stationary") -> FabricReport:
        """Energy/latency projection of ``matmul(x, w)`` on this fabric."""
        *batch, k = x_shape
        m = 1
        for b in batch:
            m *= b
        return fabric_matmul_cost(m, k, w_shape[-1], bits_a=self.spec.bits_a,
                                  bits_w=self.spec.bits_w, rows=self.spec.rows,
                                  cols=self.spec.cols, n_macros=n_macros,
                                  schedule=schedule)


# --------------------------------------------------------------------- CLI
def add_fabric_cli(ap) -> None:
    """Attach the FabricSpec flags to an argparse parser (launchers' edge)."""
    ap.add_argument("--imc", "--imc-mode", dest="imc", default=None,
                    choices=("off",) + MODES,
                    help="route every projection through the IMC fabric")
    ap.add_argument("--imc-bits", type=int, default=8,
                    help="activation precision (bits_a)")
    ap.add_argument("--imc-bits-w", type=int, default=0,
                    help="weight precision (0 -> same as --imc-bits)")
    ap.add_argument("--imc-backend", default="auto", choices=BACKENDS)
    ap.add_argument("--imc-mismatch-sigma", "--imc-noise-sigma",
                    dest="imc_mismatch_sigma", type=float, default=None,
                    help="device mismatch sigma (sim only; keyed per step)")
    ap.add_argument("--imc-comparator-sigma", type=float, default=None,
                    help="comparator offset sigma in V (sim only; keyed)")


def fabric_from_cli(args) -> Optional[FabricSpec]:
    """FabricSpec from the add_fabric_cli flags; None when --imc is off/unset."""
    if args.imc in (None, "off"):
        return None
    noise = None
    if args.imc_mismatch_sigma is not None or args.imc_comparator_sigma is not None:
        noise = NoiseSpec(mismatch_sigma=args.imc_mismatch_sigma,
                          comparator_offset_sigma=args.imc_comparator_sigma)
    return FabricSpec(bits_a=args.imc_bits,
                      bits_w=args.imc_bits_w or args.imc_bits,
                      mode=args.imc, backend=args.imc_backend, noise=noise)


def apply_fabric_cli(ap, args, cfg, *, jitted_what: str = "launcher"):
    """Shared launcher edge: fold the --imc* flags into a ModelConfig.

    Returns ``cfg`` unchanged when ``--imc`` wasn't given.  Noisy specs are
    first-class here: the launch Engine threads a per-step PRNG key through
    every jitted step, so ``--imc-noise-sigma`` runs at training/serving
    scale (seed-reproducible via the Engine's ``noise_seed``).
    """
    if args.imc is None:
        return cfg
    spec = fabric_from_cli(args)
    # spec built at the edge; imc_mode="off" clears the legacy channel so
    # the typed field (or None, for --imc off) is the one source of truth
    return dataclasses.replace(cfg, fabric=spec, imc_mode="off")
