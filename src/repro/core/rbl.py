"""Read-bit-line (RBL) charge-sharing discharge model.

The paper's MAC primitive: k active cells (stored bit AND RWL both 1) each open
a discharge path from the pre-charged RBL. After the 0.7 ns evaluation window
the RBL voltage is a monotone-decreasing function of k (Table I).

Two interchangeable models:
  * ``mode="lut"``     — exact Table I values (canonical, 8 rows only), with
                         piecewise-linear interpolation for fractional
                         "effective k" (Monte-Carlo mismatch).
  * ``mode="physics"`` — two-regime discharge fitted to Table I (rmse 12.4 mV):
                         constant-current (velocity-saturated read stack) drop
                         of ``U_LIN`` volts per active cell while V > VD_SAT,
                         then exponential (triode/RC) decay.  Extrapolates to
                         any row count / eval window (paper §III-F: larger
                         arrays scale C_RBL, shrinking the per-cell drop).

Everything is jnp-traceable and vmap-safe.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import constants as C


def rbl_voltage_physics(k, *, rows: int = C.ROWS, t_eval: float = C.T_EVAL_S):
    """Two-regime discharge model. ``k`` may be fractional (mismatch models).

    Scaling (paper §III-F): the effective bit-line capacitance grows with the
    number of rows, so the per-cell linear drop scales as (8/rows); the eval
    window scales the drop budget linearly (small-signal).
    """
    k = jnp.asarray(k, jnp.float32)
    u = C.U_LIN * (C.ROWS / rows) * (t_eval / C.T_EVAL_S)
    x = k * u  # total discharge "budget" in volts
    lin = C.V0_LEAK - x
    x_tri = jnp.maximum(x - (C.V0_LEAK - C.VD_SAT), 0.0)
    tri = C.VD_SAT * jnp.exp(-x_tri / C.VD_SAT)
    return jnp.where(lin >= C.VD_SAT, lin, tri)


def rbl_voltage_lut(k):
    """Exact Table I voltages; piecewise-linear in fractional k, clipped to [0,8]."""
    k = jnp.clip(jnp.asarray(k, jnp.float32), 0.0, float(C.ROWS))
    lut = jnp.asarray(C.V_RBL_TABLE, jnp.float32)
    lo = jnp.clip(jnp.floor(k).astype(jnp.int32), 0, C.ROWS - 1)
    frac = k - lo.astype(jnp.float32)
    return lut[lo] * (1.0 - frac) + lut[lo + 1] * frac


def rbl_voltage(k, *, rows: int = C.ROWS, t_eval: float = C.T_EVAL_S,
                mode: str = "lut"):
    """RBL voltage after evaluation for MAC count ``k`` (broadcasting)."""
    if mode == "lut":
        if rows != C.ROWS or t_eval != C.T_EVAL_S:
            raise ValueError("LUT mode is calibrated for 8 rows / 0.7 ns; "
                             "use mode='physics' for other geometries")
        return rbl_voltage_lut(k)
    if mode == "physics":
        return rbl_voltage_physics(k, rows=rows, t_eval=t_eval)
    raise ValueError(f"unknown rbl mode: {mode!r}")


def level_voltages(rows: int = C.ROWS, *, mode: str = "lut",
                   t_eval: float = C.T_EVAL_S):
    """Voltages for every possible count 0..rows (decoder calibration)."""
    ks = jnp.arange(rows + 1, dtype=jnp.float32)
    return rbl_voltage(ks, rows=rows, t_eval=t_eval, mode=mode)
