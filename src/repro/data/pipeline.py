"""Deterministic, shardable synthetic data pipeline.

Produces reproducible token streams keyed by (seed, step, host_shard) so that
  * every data-parallel host draws a disjoint batch slice,
  * restart-from-checkpoint resumes the exact stream position (the cursor is
    just the step counter — no iterator state to persist),
  * elastic re-sharding (host count change) re-partitions the same global
    stream deterministically.

The generator is a counter-based PRNG (threefry via jax.random under the
hood), i.e. random-access — the property real pipelines get from tf.data
snapshot/skip or SSTable sharding, modeled faithfully here.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_dim: int = 0  # >0: emit precomputed embeddings (modality stub)


class SyntheticStream:
    """Random-access LM batches: ``batch(step, shard, n_shards)``."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % 1:
            raise ValueError
        self._base = jax.random.key(cfg.seed)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1):
        cfg = self.cfg
        if cfg.global_batch % n_shards:
            raise ValueError(f"global_batch {cfg.global_batch} not divisible "
                             f"by {n_shards} shards")
        per = cfg.global_batch // n_shards
        key = jax.random.fold_in(jax.random.fold_in(self._base, step), shard)
        kt, ke = jax.random.split(key)
        # Markov-ish structured stream: next-token correlates with current —
        # a learnable signal so convergence tests are meaningful.
        base = jax.random.randint(kt, (per, cfg.seq_len + 1), 0,
                                  cfg.vocab_size, dtype=jnp.int32)
        drift = jnp.cumsum(base % 7, axis=1) % cfg.vocab_size
        toks = (base + drift) % cfg.vocab_size
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.frontend_dim:
            out["embeddings"] = jax.random.normal(
                ke, (per, cfg.seq_len, cfg.frontend_dim), jnp.bfloat16)
            del out["tokens"]
        return out

    def host_iterator(self, start_step: int, shard: int, n_shards: int):
        step = start_step
        while True:
            yield step, self.batch(step, shard, n_shards)
            step += 1


def batch_for_shape(cfg_model, shape, seed: int = 0):
    """Convenience: a synthetic batch matching a ShapeConfig (smoke/bench)."""
    dc = DataConfig(cfg_model.vocab_size, shape.seq_len, shape.global_batch,
                    seed=seed,
                    frontend_dim=(cfg_model.frontend_dim
                                  if cfg_model.frontend != "none" else 0))
    return SyntheticStream(dc).batch(0)


def validate_determinism(cfg: DataConfig) -> bool:
    s1, s2 = SyntheticStream(cfg), SyntheticStream(cfg)
    a = s1.batch(7, 1, 4)
    b = s2.batch(7, 1, 4)
    return all(bool(jnp.all(a[k] == b[k])) for k in a)
