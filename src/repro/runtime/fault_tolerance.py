"""Fault-tolerant step loop: checkpoint/restart with failure injection.

Wraps any (state, batch) -> state step function with:
  * periodic async checkpointing (atomic publish via repro.checkpoint),
  * automatic resume from the latest committed step after a crash,
  * a failure-injection hook (used by tests and chaos drills) that raises at
    chosen steps to prove recovery restores bit-exact state and data cursor,
  * straggler monitor integration (per-step wall-time feed),
  * telemetry: ``fault.failures`` / ``fault.resumes`` counters and a
    ``fault.step_s`` histogram in the global registry.

This is the single-controller view; at fleet scale each host runs the same
loop and the checkpoint root lives on shared storage.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.checkpoint.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.runtime.straggler import StragglerMonitor
from repro.telemetry import clock, get_registry


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FaultTolerantLoop:
    ckpt_root: str
    step_fn: Callable[[Any, Any, int], Any]  # (state, batch, step) -> state
    batch_fn: Callable[[int], Any]  # step -> batch (random-access pipeline)
    ckpt_every: int = 50
    keep_last: int = 3
    fail_at: Optional[set] = None  # steps at which to inject a crash
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)

    def __post_init__(self):
        self._ckpt = AsyncCheckpointer(self.ckpt_root, keep_last=self.keep_last)
        self._failed_once: set = set()

    def resume_or_init(self, init_state):
        step = latest_step(self.ckpt_root)
        if step is None:
            return init_state, 0
        state, step = restore(self.ckpt_root, init_state)
        get_registry().counter("fault.resumes").inc()
        return state, step + 1  # checkpoint stores post-step state

    def run(self, init_state, n_steps: int,
            metrics_cb: Optional[Callable[[int, Dict], None]] = None):
        """Run to ``n_steps`` total; crashes are re-raised after a checkpoint
        flush so an external supervisor (or the test) can restart us."""
        reg = get_registry()
        state, start = self.resume_or_init(init_state)
        for step in range(start, n_steps):
            if self.fail_at and step in self.fail_at \
                    and step not in self._failed_once:
                self._failed_once.add(step)
                self._ckpt.wait()
                reg.counter("fault.failures").inc()
                raise InjectedFailure(f"injected failure at step {step}")
            t0 = clock()
            batch = self.batch_fn(step)
            # the global step rides along so per-step noise keys (and hence
            # resumed runs) are independent of where the loop restarted
            state = self.step_fn(state, batch, step)
            dt = clock() - t0
            reg.histogram("fault.step_s").observe(dt)
            self.monitor.record_step({0: dt})
            if (step + 1) % self.ckpt_every == 0 or step == n_steps - 1:
                self._ckpt.save_async(step, state)
            if metrics_cb:
                metrics_cb(step, {"step_time_s": dt})
        self._ckpt.wait()
        return state
