"""Fault-tolerant step loop: checkpoint/restart with failure injection.

Wraps any (state, batch) -> state step function with:
  * periodic async checkpointing (atomic publish via repro.checkpoint),
  * automatic resume from the latest committed step after a crash,
  * a failure-injection hook (used by tests and chaos drills) that raises at
    chosen steps to prove recovery restores bit-exact state and data cursor,
  * straggler monitor integration — by default the loop feeds its own wall
    time as host 0; a fleet loop overrides ``host_times_fn`` so the monitor
    sees REAL per-host entries, and ``on_straggler`` escalates newly flagged
    hosts to the supervisor (the fleet loop raises there, shrinks the mesh,
    and re-enters ``run`` — which resumes from the latest checkpoint),
  * telemetry: ``fault.failures`` / ``fault.resumes`` counters and a
    ``fault.step_s`` histogram in the global registry.

At fleet scale each host runs the same loop with the checkpoint root on
shared storage; :class:`repro.fleet.FleetTrainLoop` drives one of these per
controller with the virtual/distributed coordinator supplying per-host times.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.runtime.straggler import StragglerMonitor
from repro.telemetry import clock, get_registry


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FaultTolerantLoop:
    ckpt_root: str
    step_fn: Callable[[Any, Any, int], Any]  # (state, batch, step) -> state
    batch_fn: Callable[[int], Any]  # step -> batch (random-access pipeline)
    ckpt_every: int = 50
    keep_last: int = 3
    fail_at: Optional[set] = None  # steps at which to inject a crash
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    # dt -> {host: wall_s}: what the monitor is fed each step.  None keeps
    # the single-controller default ({0: dt}); fleet loops supply the real
    # per-host times their step just measured.
    host_times_fn: Optional[Callable[[float], Dict[int, float]]] = None
    # called with hosts the monitor NEWLY flagged this step (checkpoints are
    # flushed first, so the callback may raise to force a resume-from-ckpt)
    on_straggler: Optional[Callable[[List[int]], None]] = None

    def __post_init__(self):
        self._ckpt = AsyncCheckpointer(self.ckpt_root, keep_last=self.keep_last)
        self._failed_once: set = set()

    def resume_or_init(self, init_state):
        step = latest_step(self.ckpt_root)
        if step is None:
            return init_state, 0
        state, step = restore(self.ckpt_root, init_state)
        get_registry().counter("fault.resumes").inc()
        return state, step + 1  # checkpoint stores post-step state

    def run(self, init_state, n_steps: int,
            metrics_cb: Optional[Callable[[int, Dict], None]] = None):
        """Run to ``n_steps`` total; crashes are re-raised after a checkpoint
        flush so an external supervisor (or the test) can restart us."""
        reg = get_registry()
        state, start = self.resume_or_init(init_state)
        for step in range(start, n_steps):
            if self.fail_at and step in self.fail_at \
                    and step not in self._failed_once:
                self._failed_once.add(step)
                self._ckpt.wait()
                reg.counter("fault.failures").inc()
                raise InjectedFailure(f"injected failure at step {step}")
            t0 = clock()
            batch = self.batch_fn(step)
            # the global step rides along so per-step noise keys (and hence
            # resumed runs) are independent of where the loop restarted
            state = self.step_fn(state, batch, step)
            dt = clock() - t0
            reg.histogram("fault.step_s").observe(dt)
            times = self.host_times_fn(dt) if self.host_times_fn else {0: dt}
            flagged = self.monitor.record_step(times)
            if flagged and self.on_straggler:
                self._ckpt.wait()  # flush so the callback can safely resume
                self.on_straggler(flagged)
            if (step + 1) % self.ckpt_every == 0 or step == n_steps - 1:
                self._ckpt.save_async(step, state)
            if metrics_cb:
                metrics_cb(step, {"step_time_s": dt})
        self._ckpt.wait()
        return state
