"""Gradient compression for cross-pod all-reduce: int8 + error feedback.

At multi-pod scale the 'pod' axis rides DCN/optical links an order of
magnitude slower than intra-pod ICI, so the cross-pod gradient reduction is
the first collective to saturate.  Compressing to int8 with per-tensor scales
cuts those bytes 4x (vs f32) / 2x (vs bf16); error feedback (residual carried
to the next step) keeps convergence unbiased in practice.

Composes in front of the optimizer: compress -> (all-reduce) -> decompress.
On a single host the all-reduce is the identity; the numerics (quantize +
residual) are exactly what runs at scale, so tests validate convergence.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any  # error-feedback carry, same pytree as grads (f32)


def init_compression(grads_like) -> CompressionState:
    return CompressionState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress(grads, state: CompressionState):
    """Returns ((q int8 tree, scales tree), new residual carry)."""

    def one(g, r):
        g = g.astype(jnp.float32) + r
        amax = jnp.max(jnp.abs(g))
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_r = g - q.astype(jnp.float32) * scale
        return (q, scale), new_r

    flat, treedef = jax.tree.flatten(grads)
    rflat = jax.tree.leaves(state.residual)
    qs, rs = zip(*(one(g, r) for g, r in zip(flat, rflat)))
    q_tree = jax.tree.unflatten(treedef, [q for q, _ in qs])
    s_tree = jax.tree.unflatten(treedef, [s for _, s in qs])
    return (q_tree, s_tree), CompressionState(
        jax.tree.unflatten(treedef, list(rs)))


def decompress(q_tree, s_tree):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        q_tree, s_tree)
