"""Straggler detection & mitigation policy.

At fleet scale, slow hosts (thermal throttling, failing HBM, noisy neighbors)
stretch every synchronous step.  The monitor keeps an EWMA/variance estimate
of per-host step times and flags hosts exceeding ``threshold`` x the fleet
median for ``patience`` consecutive steps; the policy layer then requests a
hot-spare swap (simulated here) or, for mild cases, recommends shrinking that
host's microbatch (work-stealing).  Pure-host-side logic: no device code, so
it is exactly what a real deployment would run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.telemetry import get_registry


def _median(values: List[float]) -> float:
    """True median: midpoint of the two central elements for even counts.

    ``sorted(v)[len(v) // 2]`` (the old spelling) returns the *upper*-middle
    element for even fleet sizes, inflating the median whenever the upper
    half is slow — which raises the swap threshold exactly when stragglers
    are present and lets them hide.
    """
    s = sorted(values)
    n = len(s)
    mid = n // 2
    if n % 2:
        return s[mid]
    return 0.5 * (s[mid - 1] + s[mid])


@dataclass
class StragglerConfig:
    threshold: float = 1.5  # x median step time
    patience: int = 3
    ewma: float = 0.7


@dataclass
class HostStats:
    ewma_time: float = 0.0
    strikes: int = 0
    flagged: bool = False


@dataclass
class StragglerMonitor:
    cfg: StragglerConfig = field(default_factory=StragglerConfig)
    hosts: Dict[int, HostStats] = field(default_factory=dict)
    swaps: List[int] = field(default_factory=list)

    def record_step(self, times: Dict[int, float]) -> List[int]:
        """Feed per-host wall times for one step; returns hosts to replace."""
        reg = get_registry()
        for h, t in times.items():
            st = self.hosts.setdefault(h, HostStats(ewma_time=t))
            st.ewma_time = self.cfg.ewma * st.ewma_time + (1 - self.cfg.ewma) * t
            reg.gauge(f"straggler.ewma_s.host{h}").set(st.ewma_time)
        med = _median([s.ewma_time for s in self.hosts.values()])
        to_swap = []
        for h, st in self.hosts.items():
            if st.ewma_time > self.cfg.threshold * med:
                st.strikes += 1
                if st.strikes >= self.cfg.patience and not st.flagged:
                    st.flagged = True
                    to_swap.append(h)
            else:
                st.strikes = 0
        if to_swap:
            reg.counter("straggler.swaps").inc(len(to_swap))
        self.swaps.extend(to_swap)
        return to_swap

    def replace_host(self, host: int):
        """Hot-spare swap completed (or the host left the fleet after a
        shrink): forget the slot's stats entirely.

        The entry is *dropped*, not zeroed: a ``HostStats(ewma_time=0.0)``
        reset would (a) bias the fleet median low until the EWMA warms back
        up — masking real stragglers for ~1/(1-ewma) steps — and (b) make
        the swapped-in host's own EWMA climb from 0 instead of its first
        real sample.  With the entry gone, :meth:`record_step`'s
        ``setdefault`` re-seeds the EWMA from the first post-swap sample
        (exactly how a brand-new host enters), and until that sample arrives
        the host contributes nothing to the median.  The per-host EWMA gauge
        is zeroed too, so dashboards don't keep showing the dead host's last
        (slow) estimate.
        """
        self.hosts.pop(host, None)
        get_registry().gauge(f"straggler.ewma_s.host{host}").set(0.0)
