"""Straggler detection & mitigation policy.

At fleet scale, slow hosts (thermal throttling, failing HBM, noisy neighbors)
stretch every synchronous step.  The monitor keeps an EWMA/variance estimate
of per-host step times and flags hosts exceeding ``threshold`` x the fleet
median for ``patience`` consecutive steps; the policy layer then requests a
hot-spare swap (simulated here) or, for mild cases, recommends shrinking that
host's microbatch (work-stealing).  Pure-host-side logic: no device code, so
it is exactly what a real deployment would run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class StragglerConfig:
    threshold: float = 1.5  # x median step time
    patience: int = 3
    ewma: float = 0.7


@dataclass
class HostStats:
    ewma_time: float = 0.0
    strikes: int = 0
    flagged: bool = False


@dataclass
class StragglerMonitor:
    cfg: StragglerConfig = field(default_factory=StragglerConfig)
    hosts: Dict[int, HostStats] = field(default_factory=dict)
    swaps: List[int] = field(default_factory=list)

    def record_step(self, times: Dict[int, float]) -> List[int]:
        """Feed per-host wall times for one step; returns hosts to replace."""
        for h, t in times.items():
            st = self.hosts.setdefault(h, HostStats(ewma_time=t))
            st.ewma_time = self.cfg.ewma * st.ewma_time + (1 - self.cfg.ewma) * t
        med = sorted(s.ewma_time for s in self.hosts.values())[len(self.hosts) // 2]
        to_swap = []
        for h, st in self.hosts.items():
            if st.ewma_time > self.cfg.threshold * med:
                st.strikes += 1
                if st.strikes >= self.cfg.patience and not st.flagged:
                    st.flagged = True
                    to_swap.append(h)
            else:
                st.strikes = 0
        self.swaps.extend(to_swap)
        return to_swap

    def replace_host(self, host: int):
        """Hot-spare swap completed: reset stats for the slot."""
        self.hosts[host] = HostStats()
