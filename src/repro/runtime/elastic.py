"""Elastic scaling: rebuild the mesh after node loss/gain and re-shard state.

Strategy (hierarchical, matches the sharding design in launch/sharding.py):
the TP ('model') extent is fixed by the model's head/ffn divisibility, so
elasticity happens on the DP axes: after a failure we snap the surviving chip
count to the largest usable (pod x data x model) grid, reload the latest
committed checkpoint (full-replica npz — resharding is a no-op at the host
level), and resume with a re-scaled global batch.

Pure host-side policy + a re-mesh helper; exercised in tests with fake
device counts and in launch/train.py's failure-recovery loop.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    global_batch: int

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_mesh(n_devices: int, *, model_parallel: int, base_batch: int,
              batch_per_replica: Optional[int] = None,
              multi_pod_threshold: int = 512) -> MeshPlan:
    """Largest (data, model) or (pod, data, model) grid using <= n_devices.

    - 'model' extent is fixed (architecture divisibility constraint).
    - remaining devices go to 'data'; if the fleet spans pods (>= threshold),
      a leading 'pod' axis of 2 is split off (hierarchical collectives).
    - global batch scales with the DP extent so per-replica batch is constant.
    """
    if n_devices < model_parallel:
        raise ValueError(f"need >= {model_parallel} devices for TP")
    dp = n_devices // model_parallel
    if batch_per_replica is None:
        batch_per_replica = max(base_batch // dp, 1)
    if n_devices >= multi_pod_threshold and dp % 2 == 0:
        plan = MeshPlan((2, dp // 2, model_parallel), ("pod", "data", "model"),
                        batch_per_replica * dp)
    else:
        plan = MeshPlan((dp, model_parallel), ("data", "model"),
                        batch_per_replica * dp)
    return plan


def shrink_after_failure(plan: MeshPlan, lost_devices: int,
                         *, model_parallel: int) -> MeshPlan:
    """Re-plan after losing ``lost_devices`` chips (drop whole DP replicas)."""
    survivors = plan.n_devices - lost_devices
    dp_old = plan.n_devices // model_parallel
    per_replica = plan.global_batch // dp_old
    return plan_mesh(survivors, model_parallel=model_parallel,
                     base_batch=plan.global_batch,
                     batch_per_replica=per_replica)


def plan_for_fleet(n_hosts: int, devices_per_host: int, *,
                   model_parallel: int, base_batch: int,
                   batch_per_replica: Optional[int] = None) -> MeshPlan:
    """Fleet-shaped entry point: plan over ``n_hosts x devices_per_host``.

    Thin sugar over :func:`plan_mesh` used by the fleet coordinator so a
    straggler shrink can re-plan in whole-host units
    (``shrink_after_failure(plan, devices_per_host * len(flagged), ...)``).
    """
    return plan_mesh(n_hosts * devices_per_host,
                     model_parallel=model_parallel, base_batch=base_batch,
                     batch_per_replica=batch_per_replica)
