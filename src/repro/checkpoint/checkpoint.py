"""Sharded checkpointing: atomic publish, async save, keep-last-k GC, restore.

Layout (one directory per step):
    <root>/step_000123/
        meta.json            {"step": 123, "tree": <treedef repr>, "n": N}
        shard_00000.npz      flat leaves [i0..i1) by insertion order
        ...
        COMMITTED            sentinel written last (atomic publish)

Properties needed at 1000+-node scale, modeled faithfully:
  * atomicity — readers only trust directories containing COMMITTED; a crash
    mid-save leaves a garbage tmp dir, never a half-readable checkpoint.
  * async — save_async() snapshots to host RAM (device_get) then writes on a
    background thread; the train loop keeps stepping.
  * sharded files — leaves are partitioned into ~shard_mb chunks so restore
    can be parallelized and no single file explodes.
  * GC — keep_last prunes old steps after each successful publish.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SENTINEL = "COMMITTED"


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


def _to_storable(arr: np.ndarray) -> np.ndarray:
    """npz has no bfloat16 — store a lossless uint16 bit-view."""
    if arr.dtype == jax.numpy.bfloat16:
        return arr.view(np.uint16)
    return arr


def _from_storable(arr: np.ndarray, like) -> np.ndarray:
    if like.dtype == jax.numpy.bfloat16 and arr.dtype == np.uint16:
        return arr.view(jax.numpy.bfloat16)
    return np.asarray(arr, dtype=like.dtype)


def save(root: str, step: int, tree: Any, *, shard_mb: int = 256,
         keep_last: int = 3) -> str:
    leaves, treedef = jax.tree.flatten(tree)
    host = [_to_storable(np.asarray(jax.device_get(x))) for x in leaves]
    tmp = _step_dir(root, step) + ".tmp"
    final = _step_dir(root, step)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    budget = shard_mb * 1024 * 1024
    shards, cur, cur_bytes = [], [], 0
    for i, arr in enumerate(host):
        cur.append(i)
        cur_bytes += arr.nbytes
        if cur_bytes >= budget:
            shards.append(cur)
            cur, cur_bytes = [], 0
    if cur:
        shards.append(cur)

    for si, idxs in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{si:05d}.npz"),
                 **{f"leaf_{i}": host[i] for i in idxs})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(host),
                   "n_shards": len(shards),
                   "treedef": str(treedef)}, f)
    with open(os.path.join(tmp, _SENTINEL), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(root, keep_last)
    return final


class AsyncCheckpointer:
    """Background-thread saver; at most one outstanding save (newer wins)."""

    def __init__(self, root: str, *, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any):
        self.wait()  # serialize: snapshot happens on caller thread
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _run():
            try:
                save(self.root, step, host, keep_last=self.keep_last)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    best = None
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            d = os.path.join(root, name)
            if os.path.exists(os.path.join(d, _SENTINEL)):
                best = max(best or -1, int(name[5:]))
    return best


def restore(root: str, tree_like: Any, step: Optional[int] = None):
    """Restore into the structure of ``tree_like``. Returns (tree, step)."""
    step = latest_step(root) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = _step_dir(root, step)
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    leaves_like, treedef = jax.tree.flatten(tree_like)
    if meta["n_leaves"] != len(leaves_like):
        raise ValueError(f"leaf count mismatch: ckpt {meta['n_leaves']} vs "
                         f"expected {len(leaves_like)}")
    host = [None] * meta["n_leaves"]
    for si in range(meta["n_shards"]):
        with np.load(os.path.join(d, f"shard_{si:05d}.npz")) as z:
            for k in z.files:
                host[int(k[5:])] = z[k]
    leaves = [_from_storable(h, l).reshape(l.shape)
              for h, l in zip(host, leaves_like)]
    return jax.tree.unflatten(treedef, leaves), step


def _gc(root: str, keep_last: int):
    steps = sorted(
        int(n[5:]) for n in os.listdir(root)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(root, n, _SENTINEL)))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)
