import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the entry point of a fresh process (the XLA flag above is read at
first jax init).  For each cell, ``Engine.aot_compile`` lowers + compiles the
step under the production mesh with explicit in_shardings,
and records memory_analysis / cost_analysis / collective traffic to JSON under
experiments/dryrun/.  Success here proves the distribution config is coherent:
sharding mismatches, non-divisible layouts, and partitioner failures all
surface as hard errors.

Usage:
    python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh multi
    python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import traceback  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, LONG_CONTEXT_ARCHS, SHAPES, get_config  # noqa: E402
from repro.launch.engine import Engine  # noqa: E402
from repro.launch.hlo_analysis import collective_bytes, roofline_terms  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def applicable(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False  # pure full-attention archs skip 500k decode (DESIGN.md)
    return True


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = OUT_DIR, overrides: dict | None = None,
             tag: str = "", paged_kv: bool = False,
             fleet_hosts: int = 1) -> dict:
    import dataclasses

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if fleet_hosts > 1:
        # per-host cell: lower/compile on ONE virtual host's sub-mesh — what
        # every process of an N-host fleet would actually run (global batch
        # still divides across hosts upstream of this step's shapes).
        from repro.launch.mesh import make_submesh, partition_devices

        host0 = partition_devices(fleet_hosts)
        mesh = make_submesh(list(host0[0]), model_parallel=16)
        engine = Engine(mesh=mesh)
    else:
        engine = Engine(mesh=make_production_mesh(multi_pod=multi_pod))
    n_dev = engine.mesh.size
    if paged_kv and shape.kind != "decode":
        raise ValueError("--paged-kv applies to decode shapes only")
    aot = engine.aot_compile(cfg, shape, paged_kv=paged_kv)
    compiled = aot.compiled
    t_lower, t_compile = aot.lower_s, aot.compile_s

    ma = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)  # reference: per-occurrence (no trip scaling)
    # full-module cost model with while-trip multiplication (hlo_costs):
    from repro.launch.hlo_costs import analyze

    costs = analyze(hlo)
    # useful model flops: 6*N*D for train, 2*N*D for inference steps
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens
    rl = roofline_terms(
        {"flops": costs.flops, "bytes accessed": costs.hbm_bytes,
         "flops_int8": costs.flops_int8},
        dict(costs.coll_by_type), model_flops_total=mf, n_devices=n_dev)

    rec = {
        "arch": arch, "shape": shape_name,
        "variant": (tag or "baseline") + ("+paged_kv" if paged_kv else ""),
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "mesh": (f"fleet{fleet_hosts}_host0" if fleet_hosts > 1
                 else "2x16x16" if multi_pod else "16x16"),
        "n_devices": n_dev, "kind": shape.kind,
        "fleet_hosts": fleet_hosts,
        "params_total": cfg.n_params(), "params_active": n_active,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device_gb": round(
                (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                 + ma.output_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
                3),
        },
        "cost": {k: cost[k] for k in ("flops", "bytes accessed")
                 if k in cost},
        "collectives": coll,
        "roofline": rl.as_dict(),
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    stem = f"{arch}__{shape_name}__{rec['mesh']}{suffix}"
    with open(os.path.join(out_dir, stem + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    import gzip

    with gzip.open(os.path.join(out_dir, stem + ".hlo.gz"), "wt") as f:
        f.write(hlo)  # enables offline re-analysis without recompiling
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override key=value (repeatable)")
    ap.add_argument("--tag", default="", help="variant tag for the output file")
    ap.add_argument("--paged-kv", action="store_true",
                    help="lower decode cells against the paged KV pool + "
                         "block table instead of the per-slot ring")
    ap.add_argument("--attn-impl", default=None, choices=["jnp", "pallas"],
                    help="paged-decode attention engine to lower (shorthand "
                         "for --override attn_impl=...)")
    ap.add_argument("--fleet-hosts", type=int, default=1,
                    help="lower the cell on ONE virtual host's sub-mesh of "
                         "an N-host fleet instead of the global mesh")
    args = ap.parse_args()

    overrides = {}
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl
    for ov in args.override:
        k, v = ov.split("=", 1)
        if v.lower() in ("true", "false"):
            v = v.lower() == "true"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        overrides[k] = v

    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            if not applicable(arch, shape_name):
                print(f"SKIP  {arch} x {shape_name} (long-context N/A)")
                continue
            if args.paged_kv and SHAPES[shape_name].kind != "decode":
                continue
            for mp in meshes:
                mesh_tag = (f"fleet{args.fleet_hosts}_host0"
                            if args.fleet_hosts > 1
                            else "2x16x16" if mp else "16x16")
                suffix = f"__{args.tag}" if args.tag else ""
                tag = f"{arch}__{shape_name}__{mesh_tag}{suffix}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"HAVE  {tag}")
                    continue
                try:
                    rec = run_cell(arch, shape_name, mp, args.out,
                                   overrides=overrides, tag=args.tag,
                                   paged_kv=args.paged_kv,
                                   fleet_hosts=args.fleet_hosts)
                    r = rec["roofline"]
                    print(f"PASS  {tag}: {rec['memory']['peak_per_device_gb']}"
                          f" GiB/dev, dominant={r['dominant']}, "
                          f"t=({r['t_compute_s']:.2e},{r['t_memory_s']:.2e},"
                          f"{r['t_collective_s']:.2e})s, "
                          f"compile={rec['compile_s']}s", flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"FAIL  {tag}: {e!r}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
