"""Engine: the one sharded, key-threaded step runtime for train/serve/dryrun.

Before this module every launcher hand-rolled the same four things — mesh
construction, axis-context install, step compilation, and (nowhere at all)
PRNG-key plumbing for noisy fabrics.  The Engine owns them once:

  * **mesh + axis context** — built via :mod:`repro.launch.compat` (the
    ``jax.set_mesh`` / ``use_mesh`` / ``with mesh:`` API drift shim), entered
    with :meth:`Engine.activate` so model-side ``shard_hint`` constraints
    resolve against the ambient mesh.
  * **compiled-step cache** — :meth:`train_step` / :meth:`prefill_step` /
    :meth:`decode_step` are memoized on ``(ModelConfig, kind, extras,
    FabricSpec, autotune geometry token)``; equal configs under an unchanged
    kernel-tuning state return the *same* jitted callable, so a
    server admitting its 100th request or a trainer resuming from a
    checkpoint never re-traces.  :attr:`Engine.stats` counts cache hits,
    distinct compiles, and XLA traces (the recompile detector the serve
    tests assert on).
  * **sharding** — param/opt/batch/cache placement from
    :mod:`repro.launch.sharding`, applied either at runtime
    (:meth:`shard_params` / :meth:`shard_batch`) or ahead-of-time
    (:meth:`aot_compile`, the dry-run path: explicit ``in_shardings`` +
    ``lower().compile()``).
  * **noise keys** — one base key per Engine (``noise_seed``), folded per
    step and per slot (:meth:`noise_key`) and passed as the trailing traced
    argument of every step, so noisy FabricSpecs are seed-reproducible at
    training/serving scale instead of per-matmul.
  * **runtime hooks** — an optional :class:`StragglerMonitor` fed by
    :meth:`observe_step_time`; flagged hosts accumulate in
    :attr:`swap_requests` for the serving/training loop to act on.
  * **telemetry** — cache hit/compile/trace counters land in a
    :class:`repro.telemetry.Registry` (``engine.cache_hits`` /
    ``engine.compiles`` / ``engine.traces``), every cached step is wrapped in
    a per-kind dispatch-time histogram (``engine.step_s.<kind>``; host-side
    wall time around the jitted call — no block, no added sync), and AOT
    lower/compile run under spans on the telemetry clock.  :attr:`stats`
    remains the cheap in-process mirror the serve tests assert on.
"""
from __future__ import annotations

import contextlib
import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.kernels import autotune
from repro.launch.compat import mesh_context
from repro.launch.mesh import dp_axes, make_test_mesh, tp_axis
from repro.launch.sharding import (partition_batch, partition_inputs,
                                   partition_params)
from repro.launch.steps import (input_specs, make_prefill_step,
                                make_serve_step, make_train_step, step_fn_for)
from repro.models.common import AxisCtx, axis_ctx
from repro.optim.adamw import AdamWConfig
from repro.runtime.straggler import StragglerMonitor
from repro.telemetry import Registry, clock, get_registry, span


@dataclass
class AotResult:
    """One dry-run cell: the lowered/compiled step and how long each took."""

    lowered: object
    compiled: object
    lower_s: float
    compile_s: float


@dataclass
class EngineStats:
    """Compilation/caching counters (the serve tests' recompile detector)."""

    compiles: int = 0  # distinct jitted step functions built
    traces: int = 0  # XLA traces through those functions (re-trace = +1)
    hits: int = 0  # compiled-step cache hits


@dataclass
class Engine:
    """One mesh, one compiled-step cache, one noise-key stream.

    ``mesh=None`` builds the small test mesh over whatever devices exist;
    pass :func:`repro.launch.mesh.make_production_mesh` for the real
    topology.  The Engine is cheap to construct; executables materialize
    lazily on first use of each ``(cfg, kind)``.
    """

    mesh: Optional[object] = None
    noise_seed: int = 0
    monitor: Optional[StragglerMonitor] = None
    stats: EngineStats = field(default_factory=EngineStats)
    registry: Optional[Registry] = None  # None -> the process-global one

    def __post_init__(self):
        if self.mesh is None:
            self.mesh = make_test_mesh()
        if self.registry is None:
            self.registry = get_registry()
        self._steps: Dict[Tuple, Callable] = {}
        self._base_key = None
        self.swap_requests: List[int] = []

    # ------------------------------------------------------------- context
    @contextlib.contextmanager
    def activate(self):
        """Install the mesh + axis context (shard_hint resolves inside)."""
        ctx = AxisCtx(dp_axes(self.mesh), tp_axis(self.mesh))
        with mesh_context(self.mesh), axis_ctx(ctx):
            yield self

    # ---------------------------------------------------------- noise keys
    def noise_key(self, step: int, slot: int = 0):
        """Per-(step, slot) PRNG key: fold_in(fold_in(base, step), slot).

        Deterministic in ``noise_seed`` — two Engines with the same seed
        replay identical noise streams (the seed-reproducibility contract
        the noisy-serve tests pin down).
        """
        if self._base_key is None:
            self._base_key = jax.random.key(self.noise_seed)
        return jax.random.fold_in(jax.random.fold_in(self._base_key, step),
                                  slot)

    # ------------------------------------------------- compiled-step cache
    def _counted(self, fn):
        @functools.wraps(fn)
        def wrapper(*args):
            self.stats.traces += 1
            self.registry.counter("engine.traces").inc()
            return fn(*args)

        return wrapper

    def _timed(self, fn, kind: str):
        """Per-kind dispatch-time histogram around a jitted step.

        Times the host-side call only — the step's result is NOT blocked on,
        so no device sync is added; callers that want device-complete times
        (the Server's decode loop) block themselves and feed
        :meth:`observe_step_time`.
        """
        hist = self.registry.histogram(f"engine.step_s.{kind}")

        @functools.wraps(fn)
        def wrapper(*args):
            if not self.registry.enabled:
                return fn(*args)
            t0 = clock()
            out = fn(*args)
            hist.observe(clock() - t0)
            return out

        return wrapper

    def _cached_step(self, cfg: ModelConfig, kind: str, extras: Tuple,
                     build: Callable[[], Callable]):
        # The autotuner's geometry token rides the key: a re-tune (or a
        # REPRO_TUNE_* pin change) changes the tile geometry baked into the
        # step's kernels, so the cached executable must not be reused.  The
        # token is stable in steady state — zero retraces while nobody tunes.
        key = (cfg, kind, extras, cfg.imc_fabric,
               autotune.geometry_token())
        step = self._steps.get(key)
        if step is None:
            step = self._steps[key] = self._timed(build(), kind)
            self.stats.compiles += 1
            self.registry.counter("engine.compiles").inc()
        else:
            self.stats.hits += 1
            self.registry.counter("engine.cache_hits").inc()
        return step

    def train_step(self, cfg: ModelConfig,
                   opt_cfg: AdamWConfig = AdamWConfig(), *,
                   donate: bool = True):
        """Jitted ``(params, opt_state, batch, key) -> (params, opt, metrics)``."""
        donate_argnums = (0, 1) if donate else ()
        return self._cached_step(
            cfg, "train", (opt_cfg, donate),
            lambda: jax.jit(self._counted(make_train_step(cfg, opt_cfg)),
                            donate_argnums=donate_argnums))

    def prefill_step(self, cfg: ModelConfig, max_new_tokens: int = 0,
                     bucket: Optional[int] = None):
        """Jitted ``(params, batch, key) -> (last_logits, cache)``.

        ``bucket`` keys one executable per padded prompt length: ragged
        admission pads each prompt up to its bucket and reuses that bucket's
        executable, so mixed-length traffic compiles ``len(buckets)`` prefill
        steps up front and never again (the zero-steady-state-recompile
        guarantee the serve tests assert via :attr:`stats`).
        """
        extras = (max_new_tokens,) if bucket is None \
            else (max_new_tokens, bucket)
        return self._cached_step(
            cfg, "prefill", extras,
            lambda: jax.jit(self._counted(
                make_prefill_step(cfg, max_new_tokens))))

    def decode_step(self, cfg: ModelConfig):
        """Jitted ``(params, cache, token, key) -> (logits, cache)``.

        The same callable serves the ring cache and the paged cache (pass
        ``block_table=`` for the latter) — distinct cache pytrees are
        distinct traces of one cached step.
        """
        return self._cached_step(
            cfg, "decode", (),
            lambda: jax.jit(self._counted(make_serve_step(cfg))))

    def admit_step(self, cfg: ModelConfig):
        """Jitted paged admission: ``(batch_cache, one_cache, table_row,
        slot) -> batch_cache`` — pure pytree surgery (scatter one request's
        freshly prefilled ring cache into the shared pools), compiled once
        so steady-state admits are data-only.
        """
        from repro.models.kv_cache import merge_prefill_cache

        return self._cached_step(
            cfg, "admit", (),
            lambda: jax.jit(self._counted(merge_prefill_cache)))

    # ------------------------------------------------------------ sharding
    def shard_params(self, cfg: ModelConfig, params):
        """Place a params pytree per the TP/FSDP partitioning rules."""
        return jax.device_put(params, partition_params(params, cfg, self.mesh))

    def shard_batch(self, cfg: ModelConfig, shape: ShapeConfig, batch):
        """Place a batch pytree (DP over the batch axis where divisible)."""
        return jax.device_put(batch,
                              partition_batch(batch, cfg, shape, self.mesh))

    def aot_compile(self, cfg: ModelConfig, shape: ShapeConfig, *,
                    donate: bool = True, paged_kv: bool = False) -> AotResult:
        """Dry-run path: lower + compile one (cfg, shape) cell ahead of time.

        Explicit ``in_shardings`` come from the partitioning rules — sharding
        mismatches, non-divisible layouts, and partitioner failures surface
        as hard errors here.  ``paged_kv`` lowers decode cells against the
        paged pool + block-table state instead of the per-slot ring.
        """
        specs = input_specs(cfg, shape, paged_kv=paged_kv)
        shardings = partition_inputs(specs, cfg, shape, self.mesh)
        step = step_fn_for(cfg, shape)
        donate_argnums = (0, 1) if (donate and shape.kind != "prefill") else ()
        t0 = clock()
        with self.activate():
            jitted = jax.jit(step, in_shardings=shardings,
                             donate_argnums=donate_argnums)
            with span("engine.aot.lower", arch=cfg.name, shape=shape.name):
                lowered = jitted.lower(*specs)
            t_lower = clock() - t0
            with span("engine.aot.compile", arch=cfg.name, shape=shape.name):
                compiled = lowered.compile()
        t_compile = clock() - t0 - t_lower
        self.registry.histogram("engine.aot.lower_s").observe(t_lower)
        self.registry.histogram("engine.aot.compile_s").observe(t_compile)
        return AotResult(lowered, compiled, t_lower, t_compile)

    # --------------------------------------------------------------- hooks
    def observe_step_time(self, dt: float, host: int = 0) -> List[int]:
        """Feed one step's wall time to the straggler monitor (if any).

        Returns hosts newly flagged for a hot-spare swap; they also
        accumulate in :attr:`swap_requests`.
        """
        self.registry.histogram("engine.observed_step_s").observe(dt)
        if self.monitor is None:
            return []
        flagged = self.monitor.record_step({host: dt})
        self.swap_requests.extend(flagged)
        return flagged

    def observe_step_times(self, times: Dict[int, float]) -> List[int]:
        """Feed ONE step's per-host wall times (fleet path).

        One ``record_step`` call with the full dict — per-host calls would
        multiply the monitor's strike cadence by the fleet size.
        """
        for dt in times.values():
            self.registry.histogram("engine.observed_step_s").observe(dt)
        if self.monitor is None:
            return []
        flagged = self.monitor.record_step(dict(times))
        self.swap_requests.extend(flagged)
        return flagged
