"""Full-module HLO cost model: flops / HBM bytes / collective bytes with
correct ``while``-loop trip-count accounting.

Why not ``compiled.cost_analysis()``: XLA's analysis counts each computation
ONCE — a jax.lax.scan over 80 transformer layers contributes its body a single
time, undercounting flops/bytes/collectives by ~80x.  This analyzer walks the
post-SPMD HLO text, builds the call graph (entry -> fusions/whiles/calls),
multiplies while bodies by their parsed trip counts, and accumulates:

  * flops             — 2*M*N*K for every ``dot`` (incl. dots inside fusions);
                        matmul-dominated models make elementwise flops noise.
  * hbm bytes         — operands+results of MATERIALIZATION ops only (dot,
                        fusion, copy, gather/scatter, dynamic-(update-)slice,
                        reduce, sort, concatenate, collectives).  Elementwise
                        ops are treated as producer-fused (a TPU fusion model:
                        the CPU backend leaves them unfused at top level, so
                        counting their operands would overcount HBM traffic
                        ~80x); fusion internals never touch HBM.
  * collective bytes  — operand bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute,
                        trip-multiplied like everything else.

All counts are per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# Ops that read/write HBM even under aggressive TPU fusion; everything
# elementwise is assumed producer-fused (never materialized).
_MATERIALIZING_OPS = frozenset({
    "dot", "convolution", "fusion", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "reduce", "reduce-window",
    "sort", "concatenate", "pad", "reverse", "select-and-scatter",
    "custom-call", "rng", "rng-bit-generator", "cholesky",
    "triangular-solve",
})

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(s: str) -> List[int]:
    m = _SHAPE_RE.search(s)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    params: Dict[str, str] = field(default_factory=dict)  # name -> shape
    ops: List[Op] = field(default_factory=list)


# params may be tuple-typed -> nested parens; greedy match up to the `->`
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
# shape group must survive tuple shapes with /*index=N*/ comments
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_CALLEE_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)="
    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def parse_module(text: str):
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if line.startswith("HloModule") or not line.strip():
            continue
        if not line.startswith(" ") and "{" in line:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
                for pm in re.finditer(r"([\w.\-]+):\s*([\w\[\],{}]+)",
                                      m.group(2)):
                    cur.params[pm.group(1)] = pm.group(2)
                continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            name, shape, opcode, operands, attrs = m.groups()
            ops = [o.strip().lstrip("%") for o in _split_operands(operands)]
            cur.ops.append(Op(name, shape, opcode, ops, attrs))
    return comps, entry


def _split_operands(s: str) -> List[str]:
    # operands may be "%a, %b" or "f32[8]{0} %a, ..." — keep last token of each
    out = []
    depth = 0
    cur = []
    for ch in s:
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            depth += ch in "([{"
            depth -= ch in ")]}"
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [tok.split()[-1] if tok.split() else "" for tok in out]


@dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_type: Dict[str, float] = field(default_factory=dict)
    flops_int8: float = 0.0  # subset of flops executed as int8 dots (2x MXU)

    def __iadd__(self, other):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.coll_bytes += other.coll_bytes
        self.flops_int8 += other.flops_int8
        for k, v in other.coll_by_type.items():
            self.coll_by_type[k] = self.coll_by_type.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.hbm_bytes * k, self.coll_bytes * k,
                     {t: v * k for t, v in self.coll_by_type.items()},
                     self.flops_int8 * k)


class HloCostModel:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._sym: Dict[str, str] = {}
        for c in self.comps.values():
            for p, s in c.params.items():
                self._sym[p] = s
            for op in c.ops:
                self._sym[op.name] = op.shape
        self._memo: Dict[str, Costs] = {}

    # ---------------------------------------------------------------- utils
    def _operand_bytes(self, op: Op) -> int:
        return sum(_shape_bytes(self._sym.get(o, "")) for o in op.operands)

    def _dot_flops(self, op: Op) -> float:
        out = _shape_dims(op.shape)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        lhs_shape = _shape_dims(self._sym.get(op.operands[0], ""))
        if m is None or not lhs_shape:
            return 0.0
        k = 1
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_shape):
                k *= lhs_shape[int(d)]
        n_out = 1
        for d in out:
            n_out *= d
        return 2.0 * n_out * k

    def _trip_count(self, cond_name: str) -> int:
        """Loop bound = the largest integer constant in the condition
        computation (scan conditions are ``iter < constant(N)``)."""
        cond = self.comps.get(cond_name)
        if not cond:
            return 1
        best = 1
        for op in cond.ops:
            if op.opcode != "constant":
                continue
            for tok in op.operands + [op.attrs or ""]:
                mm = re.fullmatch(r"(\d+)", tok.strip()) or \
                    re.search(r"constant\((\d+)\)", tok)
                if mm:
                    best = max(best, int(mm.group(1)))
        return best

    def _callees(self, op: Op) -> List[str]:
        names = []
        for m in _CALLEE_RE.finditer(op.attrs or ""):
            for n in m.group(1).split(","):
                names.append(n.strip().lstrip("%"))
        return names

    # ----------------------------------------------------------- cost walk
    def comp_costs(self, name: str, top_level: bool = True) -> Costs:
        key = f"{name}:{top_level}"
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Costs()  # cycle guard
        comp = self.comps.get(name)
        total = Costs()
        if comp is None:
            return total
        for op in comp.ops:
            oc = op.opcode
            base = oc[:-6] if oc.endswith("-start") else oc
            if oc == "dot":
                f = self._dot_flops(op)
                lhs = self._sym.get(op.operands[0], "")
                is_i8 = lhs.startswith("s8[") or lhs.startswith("u8[")
                total += Costs(f, flops_int8=f if is_i8 else 0.0)
            if base in COLLECTIVE_OPS and not oc.endswith("-done"):
                b = self._operand_bytes(op) or _shape_bytes(op.shape)
                total += Costs(0, 0, b, {base: float(b)})
            if top_level and (oc in _MATERIALIZING_OPS
                              or base in COLLECTIVE_OPS):
                # Each materialized tensor is counted ONCE (its write);
                # consumers reading it are assumed streaming.  Dots also
                # count their operand reads (weight/activation streams into
                # the MXU are true HBM traffic even when inputs were written
                # by a fused producer long before).
                if oc == "dynamic-update-slice" and len(op.operands) >= 2:
                    # in-place semantics: traffic = the update slice, not the
                    # whole buffer (a 1-token KV-cache write is ~B*KV*hd, not
                    # the full 32k-context cache)
                    b = _shape_bytes(self._sym.get(op.operands[1], ""))
                elif oc == "fusion" and "dynamic-update-slice" in op.name:
                    # fused in-place update: traffic ~= operands minus the
                    # aliased buffer (the largest operand)
                    per = [_shape_bytes(self._sym.get(o, ""))
                           for o in op.operands]
                    b = max(sum(per) - max(per, default=0), 0)
                else:
                    b = _shape_bytes(op.shape)
                if oc in ("dot", "convolution", "custom-call"):
                    b += self._operand_bytes(op)
                total += Costs(0, b)
            # descend
            if oc == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w.\-]+)", op.attrs or "")
                mc = re.search(r"condition=%?([\w.\-]+)", op.attrs or "")
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                # prefer XLA's exact annotation over cond-constant heuristics
                mt = re.search(r'known_trip_count[^}]*"n":"(\d+)"',
                               op.attrs or "")
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = self._trip_count(cond) if cond else 1
                if body:
                    total += self.comp_costs(body, True).scaled(trips)
            elif oc == "fusion":
                for callee in self._callees(op):
                    # fusion internals: dots count, HBM traffic does not
                    cc = self.comp_costs(callee, False)
                    total += Costs(cc.flops, 0, cc.coll_bytes,
                                   cc.coll_by_type, cc.flops_int8)
            elif oc in ("call", "conditional", "custom-call"):
                for callee in self._callees(op):
                    total += self.comp_costs(callee, top_level)
        self._memo[key] = total
        return total

    def entry_costs(self) -> Costs:
        if self.entry is None:
            return Costs()
        return self.comp_costs(self.entry, True)


def analyze(hlo_text: str) -> Costs:
    return HloCostModel(hlo_text).entry_costs()
