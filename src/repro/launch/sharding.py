"""Sharding rules: params (TP + intra-pod FSDP/ZeRO), optimizer, batch, cache.

Policy (see DESIGN.md §3.2):
  * TP over "model": attention heads, MLP/expert d_ff, experts, vocab.
  * FSDP/ZeRO over "data" (intra-pod only): the other large dim of every 2D+
    weight; optimizer masters/moments inherit the same specs.
  * "pod" axis: pure DP (replicated params, hierarchical grad all-reduce).
  * batch over ("pod","data"); decode KV-cache seq over "model"
    (flash-decode-style sharded softmax); long_500k (batch=1) shards cache
    seq over every axis.
  * every rule is divisibility-guarded: a non-divisible dim falls back to
    replication on that axis (correctness never depends on the spec).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import dp_axes, dp_size


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _guard(mesh, shape, spec):
    """Drop axes whose extent does not divide the dim."""
    fixed = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        fixed.append(axis if dim % _axis_size(mesh, axis) == 0 else None)
    return P(*fixed)


def _ns(mesh, shape, *spec):
    return NamedSharding(mesh, _guard(mesh, shape, spec))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


# ------------------------------------------------------------------ params
def _param_rule(path: str, shape, mesh):
    tp, dt = "model", "data"
    lead = ("blocks/groups/" in path)  # stacked (G, ...) leaves

    def spec(*axes):
        axes = ((None,) + axes) if lead else axes
        return _ns(mesh, shape, *axes)

    name = path.rsplit("/", 2)[-2:]  # e.g. ["wq", "w"]
    leaf = "/".join(name)

    if path.endswith("embed"):
        if shape[0] % mesh.shape[tp] == 0:
            return _ns(mesh, shape, tp, None)
        return _ns(mesh, shape, None, tp)
    if "lm_head" in path or "frontend_proj" in path:
        return _ns(mesh, shape, dt, tp)
    if "router" in path or "norm" in path:
        return spec()
    # MoE expert banks (E, D, F) / (E, F, D)
    if len(shape) - (1 if lead else 0) == 3 and (
            "w_gate" in path or "w_up" in path or "w_down" in path):
        if "w_down" in path:
            return spec(tp, None, dt)
        return spec(tp, dt, None)
    if leaf in ("wq/w", "wk/w", "wv/w", "w_gate/w", "w_up/w",
                "w_gate_branch/w", "w_x_branch/w", "w_a/w", "w_i/w",
                "in_proj/w"):
        return spec(dt, tp)
    if leaf in ("wo/w", "w_down/w", "w_out/w", "out_proj/w"):
        return spec(tp, dt)
    if leaf.endswith("/b") or path.endswith("lam") or path.endswith("a_log") \
            or path.endswith("d_skip") or path.endswith("dt_bias"):
        return spec(tp)
    if path.endswith("conv_w"):
        return spec(None, tp)
    if path.endswith("conv_b"):
        return spec(tp)
    return spec()


def partition_params(params_tree, cfg: ModelConfig, mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    out = [_param_rule(_path_str(p), l.shape, mesh) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def partition_opt(opt_tree, cfg: ModelConfig, mesh):
    """AdamWState(step, master, m, v): moments/masters mirror param specs."""
    pspec = partition_params(opt_tree.master, cfg, mesh)
    scalar = NamedSharding(mesh, P())
    return type(opt_tree)(scalar, pspec, pspec, pspec)


# ------------------------------------------------------------------ batch
def partition_batch(batch_tree, cfg: ModelConfig, shape: ShapeConfig, mesh):
    dp = dp_axes(mesh)
    b = shape.global_batch

    def one(leaf):
        dpb = dp if b % dp_size(mesh) == 0 else None
        return _ns(mesh, leaf.shape, dpb, *(None,) * (len(leaf.shape) - 1))

    return jax.tree.map(one, batch_tree)


# ------------------------------------------------------------------ cache
def partition_cache(cache_tree, cfg: ModelConfig, shape: ShapeConfig, mesh):
    dp = dp_axes(mesh)
    tp = "model"
    b = shape.global_batch
    dpb = dp if b % dp_size(mesh) == 0 else None
    # long-context (B=1): spread the cache sequence over everything
    seq_ax = tp if dpb is not None else tuple(dp) + (tp,)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in flat:
        def _name(p):
            return getattr(p, "name", getattr(p, "key", getattr(p, "idx", None)))

        top = _name(path[0]) if path else None
        if top == "pos":
            out.append(NamedSharding(mesh, P()))
            continue
        in_groups = top == "groups"
        field = _name(path[-1])  # 'k'|'v'|'key_pos'|'conv_state'|'ssm_state'|0|1
        shp = leaf.shape
        lead = (None,) if in_groups else ()
        core = shp[1:] if in_groups else shp
        if field in ("k", "v"):  # (B, T, KV, hd)
            spec = lead + (dpb, seq_ax, None, None)
        elif field in ("k_scale", "v_scale"):  # (B, T, KV)
            spec = lead + (dpb, seq_ax, None)
        elif field == "key_pos":  # (B, T)
            spec = lead + (dpb, seq_ax)
        elif field == "ssm_state":  # (B, H, P, N)
            spec = lead + (dpb, tp, None, None)
        elif field == "conv_state" or len(core) == 3:  # (B, cw-1, C)
            spec = lead + (dpb, None, tp)
        elif len(core) == 2:  # rglru h: (B, W)
            spec = lead + (dpb, seq_ax if dpb is None else tp)
        else:
            spec = (None,) * len(shp)
        out.append(_ns(mesh, shp, *spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------------------- full bundles
def partition_inputs(specs: Any, cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Shardings matching launch.steps.input_specs(cfg, shape)."""
    key = NamedSharding(mesh, P())  # per-step PRNG key: replicated scalar
    if shape.kind == "train":
        params, opt, batch, _ = specs
        return (partition_params(params, cfg, mesh),
                partition_opt(opt, cfg, mesh),
                partition_batch(batch, cfg, shape, mesh), key)
    if shape.kind == "prefill":
        params, batch, _ = specs
        return (partition_params(params, cfg, mesh),
                partition_batch(batch, cfg, shape, mesh), key)
    params, cache, token, *rest = specs
    out = (partition_params(params, cfg, mesh),
           partition_cache(cache, cfg, shape, mesh),
           partition_batch(token, cfg, shape, mesh), key)
    if len(rest) > 1:  # paged decode: trailing (B, max_blocks) block table
        out = out + (NamedSharding(mesh, P()),)
    return out
