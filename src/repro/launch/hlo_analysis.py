"""Post-SPMD HLO analysis: collective-traffic accounting + roofline terms.

The compiled module is the per-device program, so every byte count extracted
here is per-chip; roofline terms divide by per-chip peak rates directly.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict

from repro.core.constants import TPU_HBM_BW, TPU_ICI_BW, TPU_PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"%?([\w.\-]+)\s*=\s*(\(?[\w\[\],\s]+\)?)\s*([\w\-]+)\(")


def shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string like 'bf16[16,4096,512]{2,1,0}'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-type operand bytes of every collective in the module.

    Operand shapes are resolved through a symbol table (name -> result shape)
    built from every instruction definition in the module.
    """
    symbols: Dict[str, str] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\()?[\w]+\[[^=]*?)\s+[\w\-]+", ln)
        if m:
            symbols[m.group(1)] = m.group(2)
    out = {op: 0 for op in COLLECTIVE_OPS}
    counts = {op: 0 for op in COLLECTIVE_OPS}
    for ln in lines:
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[\w\[\],{}\s]+?\)?)\s+([\w\-]+)(?:\.\d+)?\(([^)]*)\)", ln)
        if not m:
            continue
        result_shape, opname, operands = m.groups()
        base = opname
        if base.endswith("-start") or base.endswith("-done"):
            base = base.rsplit("-", 1)[0]
        if base not in COLLECTIVE_OPS:
            continue
        if opname.endswith("-done"):
            continue  # counted at -start
        nbytes = 0
        for token in operands.split(","):
            token = token.strip().lstrip("%")
            if token in symbols:
                nbytes += shape_bytes(symbols[token])
        if nbytes == 0:  # fall back to result size
            nbytes = shape_bytes(result_shape)
        out[base] += nbytes
        counts[base] += 1
    out["_counts"] = counts
    return out


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    t_compute_s: float
    t_memory_s: float
    t_collective_s: float
    dominant: str
    model_flops_per_device: float = 0.0
    useful_flops_ratio: float = 0.0

    def as_dict(self):
        return asdict(self)


def roofline_terms(cost: dict, coll: Dict[str, int], *,
                   model_flops_total: float = 0.0,
                   n_devices: int = 1) -> Roofline:
    """Three roofline terms from per-device costs + collective bytes.

    int8 dots (cost key "flops_int8") run at 2x MXU throughput on v5e."""
    flops = float(cost.get("flops", 0.0))
    f_i8 = float(cost.get("flops_int8", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = float(sum(v for k, v in coll.items() if not k.startswith("_")))
    t_c = (flops - f_i8) / TPU_PEAK_FLOPS_BF16 \
        + f_i8 / (2 * TPU_PEAK_FLOPS_BF16)
    t_m = byts / TPU_HBM_BW
    t_x = cbytes / TPU_ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    mf = model_flops_total / max(n_devices, 1)
    return Roofline(flops, byts, cbytes, t_c, t_m, t_x, dom, mf,
                    (mf / flops) if flops else 0.0)
