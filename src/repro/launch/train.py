"""End-to-end trainer: data -> sharded train_step -> checkpoints, fault-tolerant.

Single-process entry point that scales down to 1 CPU device (examples/tests)
and up to the production mesh (same code path the dry-run lowers).  All mesh,
sharding, compilation, and noise-key concerns live in
:class:`repro.launch.engine.Engine`; this file is just the loop.

    python -m repro.launch.train --arch imc-paper-110m --steps 200 \
        --ckpt /tmp/ckpt --batch 8 --seq 256
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.configs.base import ShapeConfig
from repro.core.fabric import add_fabric_cli, apply_fabric_cli
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch.engine import Engine
from repro.optim.adamw import AdamWConfig, init_adamw
from repro.models.model import init_params
from repro.runtime.fault_tolerance import FaultTolerantLoop
from repro.runtime.straggler import StragglerMonitor
from repro.telemetry import clock


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          ckpt_root: str | None = None, ckpt_every: int = 50,
          lr: float = 3e-4, seed: int = 0, engine: Engine | None = None,
          log_every: int = 10, fail_at=None):
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=min(20, steps // 10 + 1),
                          total_steps=steps)
    engine = engine or Engine(noise_seed=seed, monitor=StragglerMonitor())
    shape = ShapeConfig("runtime", seq_len, global_batch, "train")
    stream = SyntheticStream(DataConfig(
        cfg.vocab_size, seq_len, global_batch, seed=seed,
        frontend_dim=cfg.frontend_dim if cfg.frontend != "none" else 0))

    params = init_params(jax.random.key(seed), cfg)
    opt_state = init_adamw(params)
    metrics_hist = []

    with engine.activate():
        params = engine.shard_params(cfg, params)
        jitted = engine.train_step(cfg, opt_cfg)

        def step_fn(state, batch, step):
            params, opt_state = state
            batch = engine.shard_batch(cfg, shape,
                                       jax.tree.map(jnp.asarray, batch))
            params, opt_state, metrics = jitted(params, opt_state, batch,
                                                engine.noise_key(step))
            metrics_hist.append({k: float(v) for k, v in metrics.items()})
            return (params, opt_state)

        if ckpt_root:
            loop = FaultTolerantLoop(
                ckpt_root, step_fn, lambda s: stream.batch(s),
                ckpt_every=ckpt_every, fail_at=fail_at,
                monitor=engine.monitor or StragglerMonitor())
            state = loop.run((params, opt_state), steps)
        else:
            state = (params, opt_state)
            for s in range(steps):
                t0 = clock()
                state = step_fn(state, stream.batch(s), s)
                engine.observe_step_time(clock() - t0)
                if s % log_every == 0:
                    m = metrics_hist[-1]
                    print(f"step {s:5d} loss={m['loss']:.4f} "
                          f"ce={m['ce']:.4f} gnorm={m['grad_norm']:.2f} "
                          f"({clock()-t0:.2f}s)", flush=True)
    return state, metrics_hist


def train_fleet(cfg, *, n_hosts: int, steps: int, global_batch: int,
                seq_len: int, ckpt_root: str, ckpt_every: int = 10,
                lr: float = 3e-4, seed: int = 0, model_parallel: int = 2,
                delay=None, log_every: int = 10):
    """Virtual-fleet trainer: one Engine per coordinator host, fleet monitor,
    straggler shrink + checkpoint resume (see :mod:`repro.fleet`).

    Every host steps a replica of the full state on its own sub-mesh; the
    controller's replica is what gets checkpointed and returned.  ``delay``
    injects synthetic per-host skew into observed times (chaos drills).
    """
    from repro.fleet import FleetEngine, FleetTrainLoop, LocalCoordinator
    from repro.runtime.elastic import plan_for_fleet

    coord = LocalCoordinator(n_hosts, model_parallel=model_parallel)
    fleet = FleetEngine(coord, noise_seed=seed)
    per_host = coord.hosts()[0].n_devices
    mp = model_parallel if per_host % model_parallel == 0 else 1
    plan = plan_for_fleet(n_hosts, per_host, model_parallel=mp,
                          base_batch=global_batch)

    opt_cfg = AdamWConfig(lr=lr, warmup_steps=min(20, steps // 10 + 1),
                          total_steps=steps)
    shape = ShapeConfig("runtime", seq_len, global_batch, "train")
    stream = SyntheticStream(DataConfig(
        cfg.vocab_size, seq_len, global_batch, seed=seed,
        frontend_dim=cfg.frontend_dim if cfg.frontend != "none" else 0))
    init_state = jax.tree.map(
        jax.device_get,
        (init_params(jax.random.key(seed), cfg),
         init_adamw(init_params(jax.random.key(seed), cfg))))
    metrics_hist = {}

    def make_step(engine, host):
        jitted = engine.train_step(cfg, opt_cfg, donate=False)

        def step_fn(state, batch, step):
            params, opt_state = state
            batch = engine.shard_batch(cfg, shape,
                                       jax.tree.map(jnp.asarray, batch))
            params, opt_state, metrics = jitted(params, opt_state, batch,
                                                engine.noise_key(step))
            metrics_hist.setdefault(host, []).append(
                {k: float(v) for k, v in metrics.items()})
            if host == fleet.controller and step % log_every == 0:
                m = metrics_hist[host][-1]
                print(f"[fleet {len(fleet.active_hosts())}h] step {step:5d} "
                      f"loss={m['loss']:.4f}", flush=True)
            return (params, opt_state)

        return step_fn

    loop = FleetTrainLoop(fleet, ckpt_root, make_step,
                          lambda s: stream.batch(s), plan,
                          model_parallel=mp, ckpt_every=ckpt_every,
                          delay=delay)
    state = loop.run(init_state, steps)
    return state, metrics_hist.get(fleet.controller, []), fleet, loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="imc-paper-110m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--reduce", action="store_true",
                    help="use the smoke-scale config variant")
    ap.add_argument("--fleet-hosts", type=int, default=1,
                    help="virtual fleet: partition local devices into N "
                         "hosts and train via repro.fleet (needs a device "
                         "count divisible by N)")
    add_fabric_cli(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduce_config(cfg)
    cfg = apply_fabric_cli(ap, args, cfg, jitted_what="trainer")
    if args.fleet_hosts > 1:
        import tempfile
        ckpt_root = args.ckpt or tempfile.mkdtemp(prefix="fleet_ckpt_")
        (params, _), hist, fleet, _ = train_fleet(
            cfg, n_hosts=args.fleet_hosts, steps=args.steps,
            global_batch=args.batch, seq_len=args.seq, ckpt_root=ckpt_root,
            lr=args.lr, seed=args.seed)
        print(f"fleet: {len(fleet.active_hosts())} hosts, "
              f"{fleet.total_traces()} traces total")
    else:
        (params, _), hist = train(
            cfg, steps=args.steps, global_batch=args.batch,
            seq_len=args.seq, ckpt_root=args.ckpt, lr=args.lr,
            seed=args.seed)
    losses = [m["loss"] for m in hist]
    print(f"\nfinal loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
          f"params = {sum(np.asarray(x).size for x in jax.tree.leaves(params)):,}")


if __name__ == "__main__":
    main()
