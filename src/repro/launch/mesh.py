"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

Production topology (TPU v5e):
  single-pod: 16 x 16 = 256 chips, axes ("data", "model")
  multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model")
The "pod" axis carries pure DP (hierarchical gradient all-reduce over the
slower cross-pod links); ZeRO/FSDP sharding stays intra-pod on "data".
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, model_parallel: int = 2):
    """Small mesh over whatever devices exist (unit tests)."""
    n = n_devices or len(jax.devices())
    mp = model_parallel if n % model_parallel == 0 else 1
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def make_submesh(devices, model_parallel: int = 2):
    """(data, model) mesh over an explicit device subset.

    The virtual-fleet coordinator partitions the local devices into per-host
    groups; each group gets its own mesh built here (``jax.make_mesh`` always
    spans ``jax.devices()``, so sub-meshes need the explicit constructor).
    """
    import numpy as np
    from jax.sharding import Mesh

    n = len(devices)
    mp = model_parallel if n % model_parallel == 0 else 1
    return Mesh(np.asarray(devices).reshape(n // mp, mp), ("data", "model"))


def partition_devices(n_hosts: int, devices=None):
    """Split the local devices into ``n_hosts`` equal contiguous groups."""
    devices = list(devices if devices is not None else jax.devices())
    if n_hosts < 1 or len(devices) % n_hosts != 0:
        raise ValueError(
            f"cannot split {len(devices)} devices into {n_hosts} equal "
            f"virtual hosts")
    per = len(devices) // n_hosts
    return [tuple(devices[i * per:(i + 1) * per]) for i in range(n_hosts)]


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n
