"""Step functions (train / prefill / serve) and abstract input specs.

These are the exact computations the dry-run lowers and the trainers run.
``input_specs`` returns ShapeDtypeStructs (weak-type-correct, shardable, no
device allocation) for every model input of a given (arch x shape) cell.

Every step takes a trailing per-step PRNG ``key`` (a regular traced argument,
replicated by the sharding rules).  Inside the step the key becomes the
ambient :class:`~repro.models.common.fabric_noise_key`, so noisy FabricSpecs
draw fresh, key-derived noise on every invocation of the SAME compiled
executable — noise-free specs simply never read it and XLA drops the dead
argument.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import fabric_noise_key
from repro.models.model import decode_step, init_params, loss_fn, prefill
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw


def _noise_ctx(key):
    return fabric_noise_key(key) if key is not None else contextlib.nullcontext()


# ------------------------------------------------------------------ steps
def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig()):
    def train_step(params, opt_state, batch, key=None):
        with _noise_ctx(key):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, cfg)
        new_params, new_opt, om = adamw_update(grads, opt_state, opt_cfg)
        metrics = dict(metrics, **om)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_new_tokens: int = 0):
    def prefill_step(params, batch, key=None):
        with _noise_ctx(key):
            return prefill(params, batch, cfg, max_new_tokens=max_new_tokens)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, token, key=None, block_table=None):
        with _noise_ctx(key):
            return decode_step(params, cache, token, cfg,
                               block_table=block_table)

    return serve_step


# ------------------------------------------------------------- input specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract batch for a shape cell (training or prefill prompt)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend != "none":
        batch = {"embeddings": _sds((b, s, cfg.frontend_dim), jnp.bfloat16)}
    else:
        batch = {"tokens": _sds((b, s), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = _sds((b, s), jnp.int32)
    return batch


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(init_params, cfg=cfg),
                          jax.random.key(0))


def opt_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda p: init_adamw(p), params_specs(cfg))


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract decode cache for a (arch x decode-shape) cell: the state after
    prefilling ``seq_len`` tokens (serve_step decodes token seq_len+1)."""
    b, s = shape.global_batch, shape.seq_len

    def build(params):
        if cfg.frontend != "none":
            batch = {"embeddings": jnp.zeros((b, s, cfg.frontend_dim),
                                             jnp.bfloat16)}
        else:
            batch = {"tokens": jnp.zeros((b, s), jnp.int32)}
        # steady-state ring: T_alloc == seq_len exactly ("one new token with
        # a KV cache of seq_len"); also keeps T divisible for seq-sharding
        _, cache = prefill(params, batch, cfg, max_new_tokens=0)
        return cache

    return jax.eval_shape(build, params_specs(cfg))


def paged_cache_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                      block_size: int = 16, num_blocks: int | None = None):
    """Abstract paged decode state for a cell: (pool StackCache, block table).

    Geometry mirrors :class:`repro.launch.server.Server` defaults — the
    logical span is ``seq_len`` rounded up to whole blocks, the pool holds
    ``slots * max_blocks`` blocks unless narrowed.
    """
    from repro.models.kv_cache import init_paged_cache

    b, s = shape.global_batch, shape.seq_len
    mb = -(-s // block_size)
    nb = num_blocks or b * mb

    def build(params):
        if cfg.frontend != "none":
            batch = {"embeddings": jnp.zeros((1, s, cfg.frontend_dim),
                                             jnp.bfloat16),
                     "length": jnp.asarray(s, jnp.int32)}
        else:
            batch = {"tokens": jnp.zeros((1, s), jnp.int32),
                     "length": jnp.asarray(s, jnp.int32)}
        _, one = prefill(params, batch, cfg, max_new_tokens=0)
        return init_paged_cache(one, b, nb, block_size)

    cache = jax.eval_shape(build, params_specs(cfg))
    return cache, _sds((b, mb), jnp.int32)


def token_specs(shape: ShapeConfig):
    return _sds((shape.global_batch, 1), jnp.int32)


def key_specs():
    """Abstract per-step PRNG key (typed key array, scalar)."""
    return jax.eval_shape(lambda: jax.random.key(0))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                paged_kv: bool = False):
    """All abstract inputs for the cell's step function, keyed by kind:
    train  -> (params, opt_state, batch, key)
    prefill-> (params, batch, key)
    decode -> (params, cache, token, key[, block_table] when paged_kv)
    """
    if shape.kind == "train":
        return (params_specs(cfg), opt_specs(cfg), batch_specs(cfg, shape),
                key_specs())
    if shape.kind == "prefill":
        return (params_specs(cfg), batch_specs(cfg, shape), key_specs())
    if shape.kind == "decode":
        if paged_kv:
            cache, table = paged_cache_specs(cfg, shape)
            return (params_specs(cfg), cache, token_specs(shape),
                    key_specs(), table)
        return (params_specs(cfg), cache_specs(cfg, shape),
                token_specs(shape), key_specs())
    raise ValueError(shape.kind)


def step_fn_for(cfg: ModelConfig, shape: ShapeConfig):
    if shape.kind == "train":
        return make_train_step(cfg)
    if shape.kind == "prefill":
        return make_prefill_step(cfg)
    return make_serve_step(cfg)
