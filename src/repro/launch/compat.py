"""Version-compat shims for the jax mesh-context API drift.

The "make this mesh ambient" entry point moved three times across jax
releases: 0.4.x enters the mesh itself as a context manager
(``with mesh: ...``), 0.5.x-0.6.x grew ``jax.sharding.use_mesh``, and
jax >= 0.6.2 promoted it to ``jax.set_mesh``.  The ambient-mesh *getter*
drifted in lockstep (``jax.sharding.get_abstract_mesh`` vs the legacy
``thread_resources`` env).  Every launcher and model-side sharding hint in
this repo goes through this module — the sibling of
:mod:`repro.kernels.compat` for the launch layer — so a jax upgrade stays a
one-file change.

Resolved at import time (cheap, and failures surface immediately):

  * :func:`mesh_context`  — context manager installing ``mesh`` as ambient.
  * :func:`ambient_mesh`  — the ambient (abstract or physical) mesh, or
    ``None`` when no mesh context is active.
"""
from __future__ import annotations

import jax

if hasattr(jax, "set_mesh"):  # jax >= 0.6.2
    mesh_context = jax.set_mesh
elif hasattr(jax.sharding, "use_mesh"):  # 0.5.x - 0.6.x
    mesh_context = jax.sharding.use_mesh
else:  # 0.4.x: a Mesh is its own context manager

    def mesh_context(mesh):
        """``with mesh_context(mesh):`` — ambient-mesh install, any jax."""
        return mesh


if hasattr(jax.sharding, "get_abstract_mesh"):

    def ambient_mesh():
        """The mesh installed by :func:`mesh_context`, or None outside one."""
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return None
        return mesh

else:  # 0.4.x: the resource env carries the physical mesh
    from jax._src import mesh as _mesh_lib

    def ambient_mesh():
        """The mesh installed by :func:`mesh_context`, or None outside one."""
        mesh = _mesh_lib.thread_resources.env.physical_mesh
        return None if mesh.empty else mesh
