"""Typed serving API: ``Server.submit(Request) -> Handle``, ``poll``, ``drain``.

This replaces the ``BatchedServer.run(requests)`` batch call with an admission
queue over a **paged KV cache** (see :mod:`repro.models.kv_cache`):

  * :class:`Request` carries per-request ``prompt``, ``max_new_tokens``,
    ``eos_id``, ``seed`` and ``temperature`` — no server-wide prompt length
    or decode budget.
  * :meth:`Server.submit` does **block budgeting**: a request is admitted
    only when the allocator can hand it ``ceil(len / block_size)`` blocks now
    and *reserve* the worst-case remainder (``len + max_new_tokens`` rows),
    so an admitted request can never run dry mid-decode.  Requests that can
    never fit are rejected at submit; the rest queue until blocks free up.
  * ragged admission: each prompt is right-padded to the smallest configured
    **bucket** and prefilled with a traced ``length`` scalar — one compiled
    prefill executable per bucket, zero recompiles at steady state
    (asserted via ``Engine.stats.traces``).
  * decode runs all active slots in lockstep through ONE compiled step; the
    per-slot block table rides along as a traced argument, so growing,
    finishing, and re-admitting requests is data-only.
  * finished slots release their blocks immediately (``eos_id`` or
    ``max_new_tokens``), fault injection re-queues in-flight requests
    (greedy decode makes recovered streams bit-identical), and every decode
    step's wall time feeds the Engine's straggler monitor.

``kv="ring"`` keeps the legacy geometry (one fixed ring per slot, uniform
prompt length) behind the same API — it is the oracle the paged path is
tested against and the baseline the benchmarks compare throughput with.

Serving SLOs are first-class telemetry (all host-side; nothing recorded here
ever blocks on the device beyond the block the decode loop already does for
sampling):

  * ``server.ttft_s``   — time-to-first-token histogram (submit -> the
    prefill-produced token).
  * ``server.tpot_s``   — time-per-output-token histogram (decode tokens
    only, per finished request).
  * ``server.admitted`` / ``server.rejected`` counters,
    ``server.queue_depth`` gauge.
  * ``server.block_occupancy`` gauge (+ high-water mark) fed from the
    :class:`~repro.models.kv_cache.BlockAllocator` free list.
  * ``server.decode_tokens`` counter + ``server.decode_step_s`` histogram —
    decode tokens/s is their ratio with :attr:`Server.decode_s`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.engine import Engine
from repro.models.kv_cache import (BlockAllocator, broadcast_slots,
                                   init_paged_cache)
from repro.runtime.fault_tolerance import InjectedFailure
from repro.telemetry import clock, span


@dataclass(frozen=True)
class Request:
    """One generation request (immutable; results live on the Handle)."""

    prompt: np.ndarray  # (S,) int32 token ids
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    seed: int = 0
    temperature: float = 0.0  # 0 -> greedy argmax


@dataclass
class Handle:
    """Mutable view of one submitted request's progress."""

    rid: int
    request: Request
    status: str = "queued"  # queued | active | done | rejected
    tokens: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    host: int = 0  # which fleet host serves this request (0 single-host)
    reason: str = ""  # set when rejected
    _next_pos: int = 0  # next KV position this slot writes (host-side)
    _rng: Optional[np.random.Generator] = None
    _t_submit: float = 0.0  # telemetry clock at submit (TTFT start)
    _t_first: float = 0.0  # telemetry clock at first token (TPOT start)

    @property
    def done(self) -> bool:
        return self.status == "done"


class Server:
    """Continuous-batching server over a paged (or legacy ring) KV cache.

    Parameters
    ----------
    slots: max concurrent requests (the lockstep decode batch).
    kv: ``"paged"`` (block tables, ragged admission) or ``"ring"``
        (legacy fixed-ring oracle; requires uniform ``len(prompt)`` and
        ``max_new_tokens`` across requests).
    block_size / num_blocks: paged pool geometry.  ``num_blocks`` defaults
        to ``slots * ceil(max_seq_len / block_size)`` (never blocks on
        admission); pass less to exercise queueing.
    buckets: padded prompt lengths to compile prefill for (ascending).
    max_seq_len: hard per-request cap on ``len(prompt) + max_new_tokens``;
        fixes the decode step's logical attention span.
    attn_impl: paged-decode attention engine — ``"jnp"`` (dense gather) or
        ``"pallas"`` (fused flash-decode kernel over the block table).
        ``None`` (default) picks the kernel on TPU and keeps the config's
        value elsewhere (off-TPU the kernel would run interpreted —
        correct but slow, so only tests opt in).  Ignored for ``kv="ring"``.
    host: this server's fleet host index.  Decode-step wall times feed the
        Engine's straggler monitor under this index, so a fleet of Servers
        sharing one monitor produces REAL per-host entries instead of
        everything landing on host 0 (the pre-fleet behavior).
    fail_at: decode tick indices at which to inject a crash (chaos drill).
    """

    def __init__(self, cfg, params, *, engine: Optional[Engine] = None,
                 slots: int = 4, kv: str = "paged", block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 buckets: Sequence[int] = (16, 32, 64),
                 max_seq_len: Optional[int] = None,
                 attn_impl: Optional[str] = None,
                 host: int = 0,
                 fail_at: Optional[Sequence[int]] = None):
        if kv not in ("paged", "ring"):
            raise ValueError(f"kv must be 'paged' or 'ring', got {kv!r}")
        if attn_impl is None and kv == "paged" and \
                jax.default_backend() == "tpu":
            attn_impl = "pallas"
        if attn_impl is not None and attn_impl != cfg.attn_impl:
            cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
        self.attn_impl = cfg.attn_impl if kv == "paged" else "ring"
        self.cfg, self.params = cfg, params
        self.engine = engine or Engine()
        self.slots = slots
        self.kv = kv
        self.host = host
        self.buckets = tuple(sorted(buckets))
        self.max_seq_len = max_seq_len or (max(self.buckets) + 64)
        self.block_size = block_size
        self.max_blocks = -(-self.max_seq_len // block_size)
        self.num_blocks = num_blocks or slots * self.max_blocks
        self.alloc = BlockAllocator(self.num_blocks, block_size, slots,
                                    max_blocks_per_slot=self.max_blocks)
        self.cache = None
        self.active: List[Optional[Handle]] = [None] * slots
        self.queued: List[Handle] = []
        self.handles: List[Handle] = []
        self.recoveries = 0
        self.decode_ticks = 0
        self.decode_s = 0.0  # accumulated lockstep-decode wall time
        self._fail_at = set(fail_at or ())
        self._tick = 0  # one noise key per jitted invocation
        self._ring_shape: Optional[Tuple[int, int]] = None
        self._decode = self.engine.decode_step(cfg)
        self._admit_fn = self.engine.admit_step(cfg)
        self._prefills: Dict[int, object] = {}
        reg = self.engine.registry
        self._m_admitted = reg.counter("server.admitted")
        self._m_rejected = reg.counter("server.rejected")
        self._m_recoveries = reg.counter("server.recoveries")
        self._m_decode_tokens = reg.counter("server.decode_tokens")
        self._m_queue = reg.gauge("server.queue_depth")
        self._m_occupancy = reg.gauge("server.block_occupancy")
        self._m_tok_s = reg.gauge("server.decode_tokens_per_s")
        self._m_ttft = reg.histogram("server.ttft_s")
        self._m_tpot = reg.histogram("server.tpot_s")
        self._m_step = reg.histogram("server.decode_step_s")

    def _feed_gauges(self):
        """Occupancy from the allocator's free list + queue depth (host ints)."""
        self._m_queue.set(len(self.queued))
        if self.kv == "paged":
            used = self.num_blocks - self.alloc.num_free
            self._m_occupancy.set(used / self.num_blocks)
        if self.decode_s > 0:
            self._m_tok_s.set(self._m_decode_tokens.value / self.decode_s)

    # ----------------------------------------------------------- public API
    def submit(self, request: Request) -> Handle:
        """Queue a request; returns its Handle (possibly already rejected)."""
        h = Handle(len(self.handles), request, host=self.host)
        h._t_submit = clock()
        self.handles.append(h)
        plen = int(len(request.prompt))
        worst = plen + request.max_new_tokens
        if self.kv == "paged":
            if plen > max(self.buckets):
                h.status, h.reason = "rejected", (
                    f"prompt length {plen} exceeds the largest prefill "
                    f"bucket {max(self.buckets)}")
                self._m_rejected.inc()
                return h
            if worst > self.max_seq_len or \
                    self.alloc.blocks_for(worst) > self.num_blocks:
                h.status, h.reason = "rejected", (
                    f"worst case {worst} tokens can never fit "
                    f"(max_seq_len={self.max_seq_len}, "
                    f"pool={self.num_blocks}x{self.block_size})")
                self._m_rejected.inc()
                return h
        else:
            if self._ring_shape is None:  # first request pins the geometry
                self._ring_shape = (plen, request.max_new_tokens)
            if (plen, request.max_new_tokens) != self._ring_shape:
                h.status, h.reason = "rejected", (
                    f"kv='ring' serves one uniform shape "
                    f"{self._ring_shape}, got {(plen, request.max_new_tokens)}"
                    " — use kv='paged' for ragged traffic")
                self._m_rejected.inc()
                return h
        self.queued.append(h)
        self._m_admitted.inc()
        self._m_queue.set(len(self.queued))
        return h

    def poll(self) -> List[Handle]:
        """Advance one tick (admit + one lockstep decode); returns handles
        that finished on this tick."""
        self._pump()
        self._feed_gauges()
        if not any(self.active):
            return []
        try:
            if self.decode_ticks in self._fail_at:
                self._fail_at.discard(self.decode_ticks)
                self.decode_ticks += 1
                raise InjectedFailure(
                    f"injected failure at decode tick {self.decode_ticks - 1}")
            return self._step()
        except InjectedFailure:
            self._recover()
            return []

    def drain(self) -> List[Handle]:
        """Serve every queued/active request to completion; returns all
        handles in submit order."""
        while self.queued or any(self.active):
            self.poll()
        return list(self.handles)

    # ------------------------------------------------------------ admission
    def _prefill_step(self, bucket: int):
        if bucket not in self._prefills:
            if self.kv == "paged":
                step = self.engine.prefill_step(self.cfg, max_new_tokens=0,
                                                bucket=bucket)
            else:
                plen, max_new = self._ring_shape
                step = self.engine.prefill_step(self.cfg,
                                                max_new_tokens=max_new)
            self._prefills[bucket] = step
        return self._prefills[bucket]

    def _bucket_for(self, plen: int) -> int:
        for b in self.buckets:
            if plen <= b:
                return b
        raise ValueError(f"no bucket holds a length-{plen} prompt")

    def _next_key(self, slot: int = 0):
        k = self.engine.noise_key(self._tick, slot)
        self._tick += 1
        return k

    def _pump(self):
        """Admit queued requests into free slots while blocks allow."""
        for slot in range(self.slots):
            if not self.queued:
                return
            if self.active[slot] is not None:
                continue
            h = self.queued[0]
            plen = len(h.request.prompt)
            if self.kv == "paged":
                need = self.alloc.blocks_for(plen)
                reserve = self.alloc.blocks_for(
                    plen + h.request.max_new_tokens) - need
                if not self.alloc.can_admit(need + reserve):
                    return  # FIFO: wait for blocks instead of starving h
                self.alloc.alloc(slot, need, reserve=reserve)
            self.queued.pop(0)
            self._admit(h, slot)

    def _admit(self, h: Handle, slot: int):
        req = h.request
        plen = len(req.prompt)
        prompt = np.asarray(req.prompt, np.int32)
        if self.kv == "paged":
            bucket = self._bucket_for(plen)
            padded = np.zeros((bucket,), np.int32)
            padded[:plen] = prompt
            batch = {"tokens": jnp.asarray(padded[None]),
                     "length": jnp.asarray(plen, jnp.int32)}
            table_row = jnp.asarray(self.alloc.table_row(slot))
        else:
            batch = {"tokens": jnp.asarray(prompt[None])}
            table_row = jnp.zeros((self.max_blocks,), jnp.int32)  # unused
        bucket = None if self.kv == "ring" else len(padded)
        with span("server.prefill", rid=h.rid, len=plen, bucket=bucket):
            logits, cache1 = self._prefill_step(bucket)(
                self.params, batch, self._next_key(slot))
        if self.cache is None:
            if self.kv == "paged":
                self.cache = init_paged_cache(cache1, self.slots,
                                              self.num_blocks,
                                              self.block_size)
            else:
                self.cache = jax.tree.map(
                    lambda o: broadcast_slots(o, self.slots), cache1)
        self.cache = self._admit_fn(self.cache, cache1, table_row,
                                    jnp.asarray(slot, jnp.int32))
        h._rng = np.random.default_rng(req.seed)
        h.tokens = [self._sample(h, np.asarray(logits[0]))]
        h._t_first = clock()
        self._m_ttft.observe(h._t_first - h._t_submit)
        h._next_pos = plen
        h.status, h.slot = "active", slot
        self.active[slot] = h
        if self._finished(h):
            self._retire(h)

    # --------------------------------------------------------------- decode
    def _sample(self, h: Handle, logits_row: np.ndarray) -> int:
        if h.request.temperature <= 0:
            return int(np.argmax(logits_row))
        z = logits_row.astype(np.float64) / h.request.temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(h._rng.choice(len(p), p=p))

    def _finished(self, h: Handle) -> bool:
        if len(h.tokens) >= h.request.max_new_tokens:
            return True
        return h.request.eos_id is not None and \
            h.tokens[-1] == h.request.eos_id

    def _retire(self, h: Handle):
        h.status = "done"
        if len(h.tokens) > 1:  # TPOT covers decode tokens only
            self._m_tpot.observe(
                (clock() - h._t_first) / (len(h.tokens) - 1))
        if self.kv == "paged":
            self.alloc.release(h.slot)
        self.active[h.slot] = None

    def _step(self) -> List[Handle]:
        toks = np.zeros((self.slots, 1), np.int32)
        for i, h in enumerate(self.active):
            if h is not None:
                toks[i, 0] = h.tokens[-1]
                if self.kv == "paged":  # grow the table across a boundary
                    while self.alloc.blocks_for(h._next_pos + 1) > \
                            len(self.alloc.slot_blocks(i)):
                        self.alloc.append(i)
        t0 = clock()
        with span("server.decode", tick=self.decode_ticks):
            if self.kv == "paged":
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(toks),
                    self._next_key(), jnp.asarray(self.alloc.table()))
            else:
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(toks),
                    self._next_key())
            logits = np.asarray(logits)  # block on the step before timing it
        dt = clock() - t0
        self.decode_s += dt
        self._m_step.observe(dt)
        self.engine.observe_step_time(dt, host=self.host)
        self.decode_ticks += 1
        finished = []
        n_active = 0
        for i, h in enumerate(self.active):
            if h is None:
                continue
            n_active += 1
            h.tokens.append(self._sample(h, logits[i]))
            h._next_pos += 1
            if self._finished(h):
                self._retire(h)
                finished.append(h)
        self._m_decode_tokens.inc(n_active)
        self._feed_gauges()
        return finished

    # -------------------------------------------------------------- faults
    def _recover(self):
        """Re-queue in-flight requests from scratch (streams are replayed
        deterministically: per-request rngs reset with the request seed)."""
        requeued = []
        for i, h in enumerate(self.active):
            if h is not None:
                h.tokens = []
                h.status, h.slot, h._rng = "queued", None, None
                requeued.append(h)
            self.active[i] = None
            if self.kv == "paged":
                self.alloc.release(i)
        self.cache = None
        self.queued = requeued + self.queued
        self.recoveries += 1
        self._m_recoveries.inc()
        self._feed_gauges()
        self.alloc.check()
