"""Legacy batched serving driver (fixed-ring slots, uniform prompt length).

:class:`BatchedServer` models the pre-paging serving shape: one fixed-length
KV ring of ``prompt_len + max_new`` rows per slot, a single shared prompt
length, and a batch-style ``run(requests)`` entry point.  It remains here as
the **oracle** — the paged serving stack in :mod:`repro.launch.server` is
asserted bit-identical to it — but new code should use the typed
:class:`~repro.launch.server.Server` API (``submit``/``poll``/``drain``),
which adds ragged admission, per-request budgets, and block-pool memory
accounting.  ``BatchedServer.run`` emits a :class:`DeprecationWarning`
pointing there.

The CLI below serves through the new Server (``--kv ring`` for the legacy
geometry):

    python -m repro.launch.serve --arch qwen2.5-3b --reduce --requests 6
    python -m repro.launch.serve --arch qwen2.5-3b --reduce --requests 6 \
        --imc-mode sim --imc-noise-sigma 0.05 --seed 7
"""
from __future__ import annotations

import argparse
import time
import warnings
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.fabric import add_fabric_cli, apply_fabric_cli
from repro.launch.engine import Engine
from repro.models.kv_cache import broadcast_slots as _broadcast_slots
from repro.models.kv_cache import set_slot
from repro.models.model import init_params
from repro.runtime.fault_tolerance import InjectedFailure
from repro.runtime.straggler import StragglerMonitor


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


def _set_slot(b, o, slot):
    """Write one request's cache leaf (B=1) into the batch cache at ``slot``
    (shared slot-surgery primitives live in :mod:`repro.models.kv_cache`)."""
    return set_slot(b, o, slot)


class BatchedServer:
    """Fixed-slot continuous batching (slots = max concurrent requests)."""

    def __init__(self, cfg, params, slots: int = 4, prompt_len: int = 32,
                 max_new: int = 16, engine: Optional[Engine] = None):
        self.cfg, self.params = cfg, params
        self.engine = engine or Engine()
        self.slots = slots
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.active: List[Optional[Request]] = [None] * slots
        self.cache = None
        self.recoveries = 0
        self._tick = 0  # one noise key per jitted invocation (prefill/decode)
        self._decode = self.engine.decode_step(cfg)
        self._prefill = self.engine.prefill_step(cfg, max_new_tokens=max_new)

    def _next_key(self, slot: int = 0):
        k = self.engine.noise_key(self._tick, slot)
        self._tick += 1
        return k

    def _admit(self, req: Request, slot: int):
        batch = {"tokens": jnp.asarray(req.prompt[None])}
        logits, cache1 = self._prefill(self.params, batch,
                                       self._next_key(slot))
        req.out.append(int(jnp.argmax(logits[0])))
        if self.cache is None:
            # materialize the batch cache by broadcasting the first request
            self.cache = jax.tree.map(
                lambda o: _broadcast_slots(o, self.slots), cache1)
        self.cache = jax.tree.map(
            lambda b, o: _set_slot(b, o, slot), self.cache, cache1)
        self.active[slot] = req

    def step(self):
        """One lockstep decode over all active slots."""
        toks = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.active):
            if r and not r.done:
                toks[i, 0] = r.out[-1]
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks), self._next_key())
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.engine.observe_step_time(time.perf_counter() - t0)
        for i, r in enumerate(self.active):
            if r and not r.done:
                r.out.append(int(nxt[i]))
                if len(r.out) >= r.max_new:
                    r.done = True
                    self.active[i] = None  # retire slot
        return nxt

    def _recover(self) -> List[Request]:
        """Drop the in-flight batch state and re-queue unfinished requests.

        Greedy decode is deterministic, so replaying a request from its
        prompt reproduces the exact token stream the crash interrupted.
        """
        requeued = []
        for i, r in enumerate(self.active):
            if r is not None:
                r.out.clear()
                r.done = False
                requeued.append(r)
            self.active[i] = None
        self.cache = None
        self.recoveries += 1
        return requeued

    def run(self, requests: List[Request], *, fail_at=None):
        """Serve ``requests`` to completion; returns (requests, tokens/sec).

        ``fail_at``: decode-step indices at which to inject a crash once
        (chaos drill exercising the recovery path).

        .. deprecated:: use :class:`repro.launch.server.Server`
           (``submit``/``poll``/``drain``) — typed per-request budgets,
           ragged prompts, and paged KV memory accounting behind the same
           lockstep decode loop.
        """
        warnings.warn(
            "BatchedServer.run is deprecated; use repro.launch.server.Server"
            " (submit/poll/drain) — BatchedServer remains only as the"
            " fixed-ring oracle for the paged serving tests.",
            DeprecationWarning, stacklevel=2)
        pending = list(requests)
        fail_at = set(fail_at or ())
        nstep = 0
        t0 = time.time()
        while pending or any(self.active):
            for i in range(self.slots):
                if self.active[i] is None and pending:
                    self._admit(pending.pop(0), i)
            if any(self.active):
                try:
                    if nstep in fail_at:
                        fail_at.discard(nstep)
                        raise InjectedFailure(
                            f"injected failure at decode step {nstep}")
                    self.step()
                except InjectedFailure:
                    pending = self._recover() + pending
                nstep += 1
        dt = time.time() - t0
        # delivered tokens only: work discarded by a recovery doesn't count
        ntok = sum(len(r.out) for r in requests)
        return requests, ntok / max(dt, 1e-9)


def main():
    from repro.launch.server import Request as ServeRequest
    from repro.launch.server import Server

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--kv", default="paged", choices=["paged", "ring"],
                    help="paged block-table cache or the legacy fixed ring")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0,
                    help="noise-key seed (noisy serve is reproducible in it)")
    add_fabric_cli(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduce_config(cfg)
    cfg = apply_fabric_cli(ap, args, cfg, jitted_what="server")
    rng = np.random.default_rng(0)
    params = init_params(jax.random.key(0), cfg)
    engine = Engine(noise_seed=args.seed, monitor=StragglerMonitor())
    bucket = max(16, args.prompt_len)
    t0 = time.time()
    with engine.activate():
        server = Server(cfg, params, engine=engine, slots=args.slots,
                        kv=args.kv, block_size=args.block_size,
                        buckets=(bucket,),
                        max_seq_len=bucket + args.max_new)
        handles = [server.submit(ServeRequest(
            rng.integers(0, cfg.vocab_size,
                         size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new)) for _ in range(args.requests)]
        server.drain()
    dt = time.time() - t0
    ntok = sum(len(h.tokens) for h in handles)
    for h in handles:
        print(f"req{h.rid}: {len(h.tokens)} tokens -> {h.tokens[:8]}...")
    print(f"throughput: {ntok / max(dt, 1e-9):.1f} tok/s "
          f"({args.kv} lockstep decode; "
          f"{engine.stats.compiles} compiled steps, "
          f"{engine.stats.traces} traces)")


if __name__ == "__main__":
    main()
