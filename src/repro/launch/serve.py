"""Batched serving driver: continuous-batching decode loop over a request queue.

Models the production serving shape: prefill each arriving request, merge its
KV cache into the running batch at a free slot, decode all active slots in
lockstep with ONE sharded serve_step per token, retire finished requests.
Slot merge/retire is pure pytree surgery, so the decode step stays a single
compiled executable (no recompiles at steady state).

    python -m repro.launch.serve --arch qwen2.5-3b --reduce --requests 6
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.fabric import add_fabric_cli, apply_fabric_cli
from repro.launch.mesh import dp_axes, make_test_mesh, tp_axis
from repro.models.common import AxisCtx, axis_ctx
from repro.models.model import decode_step, init_params, prefill


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


def _batch_axis(one) -> int:
    """Batch axis of a B=1 cache leaf: grouped leaves are (G, 1, ...) ->
    axis 1; tail leaves are (1, ...) -> axis 0 (pos scalars handled upstream).
    """
    return 1 if one.ndim >= 2 and one.shape[1] == 1 else 0


def _set_slot(b, o, slot):
    """Write one request's cache leaf (B=1) into the batch cache at ``slot``.

    All requests in this driver share a prompt length, so the scalar ``pos``
    is identical across slots and passes through unchanged.
    """
    if b.ndim == 0:
        return b
    idx = [slice(None)] * b.ndim
    idx[_batch_axis(o)] = slice(slot, slot + 1)
    return b.at[tuple(idx)].set(o)


class BatchedServer:
    """Fixed-slot continuous batching (slots = max concurrent requests)."""

    def __init__(self, cfg, params, slots: int = 4, prompt_len: int = 32,
                 max_new: int = 16):
        self.cfg, self.params = cfg, params
        self.slots = slots
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.active: List[Optional[Request]] = [None] * slots
        self.cache = None
        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, c, t, cfg))
        self._prefill = jax.jit(
            lambda p, b: prefill(p, b, cfg, max_new_tokens=max_new))

    def _admit(self, req: Request, slot: int):
        batch = {"tokens": jnp.asarray(req.prompt[None])}
        logits, cache1 = self._prefill(self.params, batch)
        req.out.append(int(jnp.argmax(logits[0])))
        if self.cache is None:
            # materialize the batch cache by broadcasting the first request
            self.cache = jax.tree.map(
                lambda o: _broadcast_slots(o, self.slots), cache1)
        self.cache = jax.tree.map(
            lambda b, o: _set_slot(b, o, slot), self.cache, cache1)
        self.active[slot] = req

    def step(self):
        """One lockstep decode over all active slots."""
        toks = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.active):
            if r and not r.done:
                toks[i, 0] = r.out[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, r in enumerate(self.active):
            if r and not r.done:
                r.out.append(int(nxt[i]))
                if len(r.out) >= r.max_new:
                    r.done = True
                    self.active[i] = None  # retire slot

    def run(self, requests: List[Request]):
        pending = list(requests)
        t0 = time.time()
        ntok = 0
        while pending or any(self.active):
            for i in range(self.slots):
                if self.active[i] is None and pending:
                    self._admit(pending.pop(0), i)
            if any(self.active):
                self.step()
                ntok += sum(1 for r in self.active if r)
        dt = time.time() - t0
        return requests, ntok / max(dt, 1e-9)


def _broadcast_slots(one, slots):
    if one.ndim == 0:
        return one
    axis = _batch_axis(one)
    reps = [1] * one.ndim
    reps[axis] = slots
    return jnp.tile(jnp.zeros_like(one), reps)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    add_fabric_cli(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduce_config(cfg)
    cfg = apply_fabric_cli(ap, args, cfg, jitted_what="server")
    mesh = make_test_mesh()
    rng = np.random.default_rng(0)
    params = init_params(jax.random.key(0), cfg)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=args.prompt_len).astype(np.int32),
                    args.max_new) for i in range(args.requests)]
    with jax.set_mesh(mesh), axis_ctx(AxisCtx(dp_axes(mesh), tp_axis(mesh))):
        server = BatchedServer(cfg, params, slots=args.slots,
                               prompt_len=args.prompt_len,
                               max_new=args.max_new)
        done, tps = server.run(reqs)
    for r in done:
        print(f"req{r.rid}: {len(r.out)} tokens -> {r.out[:8]}...")
    print(f"throughput: {tps:.1f} tok/s (batched lockstep decode)")


if __name__ == "__main__":
    main()
