"""Batched serving driver: continuous-batching decode loop over a request queue.

Models the production serving shape: prefill each arriving request, merge its
KV cache into the running batch at a free slot, decode all active slots in
lockstep with ONE sharded serve_step per token, retire finished requests.
Slot merge/retire is pure pytree surgery, so the decode step stays a single
compiled executable (no recompiles at steady state — asserted by tests via
``Engine.stats``).

The :class:`~repro.launch.engine.Engine` owns mesh, step compilation, and the
per-invocation PRNG keys, so noisy fabrics (``--imc-noise-sigma``) serve
seed-reproducibly.  Runtime hooks ride the loop: every decode step's wall
time feeds the Engine's straggler monitor, and ``fail_at=`` injects crashes
(chaos drills) that the server survives by re-queuing in-flight requests —
greedy decode makes the recovered token streams bit-identical.

    python -m repro.launch.serve --arch qwen2.5-3b --reduce --requests 6
    python -m repro.launch.serve --arch qwen2.5-3b --reduce --requests 6 \
        --imc-mode sim --imc-noise-sigma 0.05 --seed 7
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.fabric import add_fabric_cli, apply_fabric_cli
from repro.launch.engine import Engine
from repro.models.model import init_params
from repro.runtime.fault_tolerance import InjectedFailure
from repro.runtime.straggler import StragglerMonitor


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


def _batch_axis(one) -> int:
    """Batch axis of a B=1 cache leaf: grouped leaves are (G, 1, ...) ->
    axis 1; tail leaves are (1, ...) -> axis 0 (pos scalars handled upstream).
    """
    return 1 if one.ndim >= 2 and one.shape[1] == 1 else 0


def _set_slot(b, o, slot):
    """Write one request's cache leaf (B=1) into the batch cache at ``slot``.

    The scalar ``pos`` of a fresh (B=1) cache lands in the batch cache's
    per-slot pos vector, so slots admitted at different ticks decode at
    their own sequence positions.
    """
    if b.ndim == 0:
        return b
    idx = [slice(None)] * b.ndim
    idx[_batch_axis(o) if o.ndim else 0] = slice(slot, slot + 1)
    return b.at[tuple(idx)].set(o)


class BatchedServer:
    """Fixed-slot continuous batching (slots = max concurrent requests)."""

    def __init__(self, cfg, params, slots: int = 4, prompt_len: int = 32,
                 max_new: int = 16, engine: Optional[Engine] = None):
        self.cfg, self.params = cfg, params
        self.engine = engine or Engine()
        self.slots = slots
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.active: List[Optional[Request]] = [None] * slots
        self.cache = None
        self.recoveries = 0
        self._tick = 0  # one noise key per jitted invocation (prefill/decode)
        self._decode = self.engine.decode_step(cfg)
        self._prefill = self.engine.prefill_step(cfg, max_new_tokens=max_new)

    def _next_key(self, slot: int = 0):
        k = self.engine.noise_key(self._tick, slot)
        self._tick += 1
        return k

    def _admit(self, req: Request, slot: int):
        batch = {"tokens": jnp.asarray(req.prompt[None])}
        logits, cache1 = self._prefill(self.params, batch,
                                       self._next_key(slot))
        req.out.append(int(jnp.argmax(logits[0])))
        if self.cache is None:
            # materialize the batch cache by broadcasting the first request
            self.cache = jax.tree.map(
                lambda o: _broadcast_slots(o, self.slots), cache1)
        self.cache = jax.tree.map(
            lambda b, o: _set_slot(b, o, slot), self.cache, cache1)
        self.active[slot] = req

    def step(self):
        """One lockstep decode over all active slots."""
        toks = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.active):
            if r and not r.done:
                toks[i, 0] = r.out[-1]
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks), self._next_key())
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.engine.observe_step_time(time.perf_counter() - t0)
        for i, r in enumerate(self.active):
            if r and not r.done:
                r.out.append(int(nxt[i]))
                if len(r.out) >= r.max_new:
                    r.done = True
                    self.active[i] = None  # retire slot
        return nxt

    def _recover(self) -> List[Request]:
        """Drop the in-flight batch state and re-queue unfinished requests.

        Greedy decode is deterministic, so replaying a request from its
        prompt reproduces the exact token stream the crash interrupted.
        """
        requeued = []
        for i, r in enumerate(self.active):
            if r is not None:
                r.out.clear()
                r.done = False
                requeued.append(r)
            self.active[i] = None
        self.cache = None
        self.recoveries += 1
        return requeued

    def run(self, requests: List[Request], *, fail_at=None):
        """Serve ``requests`` to completion; returns (requests, tokens/sec).

        ``fail_at``: decode-step indices at which to inject a crash once
        (chaos drill exercising the recovery path).
        """
        pending = list(requests)
        fail_at = set(fail_at or ())
        nstep = 0
        t0 = time.time()
        while pending or any(self.active):
            for i in range(self.slots):
                if self.active[i] is None and pending:
                    self._admit(pending.pop(0), i)
            if any(self.active):
                try:
                    if nstep in fail_at:
                        fail_at.discard(nstep)
                        raise InjectedFailure(
                            f"injected failure at decode step {nstep}")
                    self.step()
                except InjectedFailure:
                    pending = self._recover() + pending
                nstep += 1
        dt = time.time() - t0
        # delivered tokens only: work discarded by a recovery doesn't count
        ntok = sum(len(r.out) for r in requests)
        return requests, ntok / max(dt, 1e-9)


def _broadcast_slots(one, slots):
    if one.ndim == 0:  # scalar pos -> per-slot position vector
        return jnp.zeros((slots,), one.dtype)
    axis = _batch_axis(one)
    reps = [1] * one.ndim
    reps[axis] = slots
    return jnp.tile(jnp.zeros_like(one), reps)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0,
                    help="noise-key seed (noisy serve is reproducible in it)")
    add_fabric_cli(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduce_config(cfg)
    cfg = apply_fabric_cli(ap, args, cfg, jitted_what="server")
    rng = np.random.default_rng(0)
    params = init_params(jax.random.key(0), cfg)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=args.prompt_len).astype(np.int32),
                    args.max_new) for i in range(args.requests)]
    engine = Engine(noise_seed=args.seed, monitor=StragglerMonitor())
    with engine.activate():
        server = BatchedServer(cfg, params, slots=args.slots,
                               prompt_len=args.prompt_len,
                               max_new=args.max_new, engine=engine)
        done, tps = server.run(reqs)
    for r in done:
        print(f"req{r.rid}: {len(r.out)} tokens -> {r.out[:8]}...")
    print(f"throughput: {tps:.1f} tok/s (batched lockstep decode; "
          f"{engine.stats.compiles} compiled steps, "
          f"{engine.stats.traces} traces)")


if __name__ == "__main__":
    main()
