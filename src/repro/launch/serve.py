"""Serving CLI — drives :class:`repro.launch.server.Server` from the shell.

The pre-paging ``BatchedServer`` (fixed-ring slots, uniform prompt length,
batch-style ``run(requests)``) finished its deprecation cycle and is gone;
``Server(kv="ring")`` reproduces the same fixed-ring geometry behind the
typed ``submit``/``poll``/``drain`` API, with ragged admission, per-request
budgets, and block-pool memory accounting on the ``kv="paged"`` path.

    python -m repro.launch.serve --arch qwen2.5-3b --reduce --requests 6
    python -m repro.launch.serve --arch qwen2.5-3b --reduce --requests 6 \
        --imc-mode sim --imc-noise-sigma 0.05 --seed 7
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.fabric import add_fabric_cli, apply_fabric_cli
from repro.launch.engine import Engine
from repro.models.model import init_params
from repro.runtime.straggler import StragglerMonitor
from repro.telemetry import clock


def main():
    from repro.launch.server import Request, Server

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--kv", default="paged", choices=["paged", "ring"],
                    help="paged block-table cache or the legacy fixed ring")
    ap.add_argument("--attn-impl", default=None,
                    choices=["jnp", "pallas"],
                    help="paged-decode attention engine (default: pallas on "
                         "TPU, jnp elsewhere)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0,
                    help="noise-key seed (noisy serve is reproducible in it)")
    ap.add_argument("--fleet-hosts", type=int, default=1,
                    help="virtual fleet: partition local devices into N "
                         "hosts, round-robin requests, report merged SLOs")
    add_fabric_cli(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduce_config(cfg)
    cfg = apply_fabric_cli(ap, args, cfg, jitted_what="server")
    rng = np.random.default_rng(0)
    params = init_params(jax.random.key(0), cfg)
    bucket = max(16, args.prompt_len)
    server_kw = dict(slots=args.slots, kv=args.kv,
                     block_size=args.block_size, buckets=(bucket,),
                     attn_impl=args.attn_impl,
                     max_seq_len=bucket + args.max_new)
    requests = [Request(
        rng.integers(0, cfg.vocab_size,
                     size=args.prompt_len).astype(np.int32),
        max_new_tokens=args.max_new) for _ in range(args.requests)]
    t0 = clock()
    if args.fleet_hosts > 1:
        from repro.fleet import FleetEngine, FleetServer, LocalCoordinator

        fleet = FleetEngine(LocalCoordinator(args.fleet_hosts),
                            noise_seed=args.seed)
        server = FleetServer(cfg, params, fleet, **server_kw)
        handles = [server.submit(r) for r in requests]
        server.drain()
        dt = clock() - t0
        slos = server.slos()
        traces = fleet.total_traces()
    else:
        engine = Engine(noise_seed=args.seed, monitor=StragglerMonitor())
        with engine.activate():
            server = Server(cfg, params, engine=engine, **server_kw)
            handles = [server.submit(r) for r in requests]
            server.drain()
        dt = clock() - t0
        slos = None
        traces = engine.stats.traces
    ntok = sum(len(h.tokens) for h in handles)
    for h in handles:
        print(f"req{h.rid}: {len(h.tokens)} tokens -> {h.tokens[:8]}...")
    print(f"throughput: {ntok / max(dt, 1e-9):.1f} tok/s "
          f"({args.kv} lockstep decode, attn={server.attn_impl}; "
          f"{traces} traces)")
    if slos is not None:
        print(f"fleet SLOs (n_hosts={slos.get('n_hosts')}): "
              f"ttft_ms={slos['ttft_ms']} tpot_ms={slos['tpot_ms']} "
              f"occupancy_peak={slos['occupancy_peak']}")


if __name__ == "__main__":
    main()
