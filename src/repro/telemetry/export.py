"""Snapshot exporters: JSON dicts, markdown tables, and BENCH_imc.json merge.

Snapshots are explicit and pull-based — nothing here runs unless called, so
the record path (see :mod:`repro.telemetry.registry`) stays write-only.  Three
consumers:

  * ``snapshot()``      — the raw {counters, gauges, histograms} dict
                          (JSON-serializable as-is).
  * ``to_markdown()``   — human-readable tables for CI job summaries / logs.
  * ``merge_into_bench()`` — attach the snapshot to a ``BENCH_imc.json``
                          record, so serve benches carry their TTFT/TPOT/
                          occupancy alongside tokens/s and ``--compare``
                          can diff them across runs.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

from repro.telemetry.registry import Registry, get_registry

__all__ = ["snapshot", "to_markdown", "merge_into_bench", "write_json"]


def snapshot(registry: Optional[Registry] = None) -> Dict:
    """JSON-serializable state of every metric in ``registry`` (global
    default)."""
    return (registry or get_registry()).snapshot()


def write_json(path: str, registry: Optional[Registry] = None) -> str:
    with open(path, "w") as f:
        json.dump(snapshot(registry), f, indent=1)
    return path


def _fmt(v, scale: float = 1.0) -> str:
    if v is None:
        return "—"
    if isinstance(v, float):
        return f"{v * scale:.4g}"
    return str(v)


def to_markdown(snap: Optional[Dict] = None,
                registry: Optional[Registry] = None) -> str:
    """Markdown tables (counters+gauges, then histogram percentiles in ms)."""
    snap = snap or snapshot(registry)
    lines = []
    if snap.get("counters") or snap.get("gauges"):
        lines += ["| metric | value |", "|---|---|"]
        for name, v in snap.get("counters", {}).items():
            lines.append(f"| {name} | {_fmt(v)} |")
        for name, g in snap.get("gauges", {}).items():
            lines.append(f"| {name} | {_fmt(g['value'])} "
                         f"(hwm {_fmt(g['hwm'])}) |")
    if snap.get("histograms"):
        lines += ["", "| histogram | count | p50 ms | p95 ms | p99 ms | "
                  "max ms |", "|---|---|---|---|---|---|"]
        for name, h in snap["histograms"].items():
            if not h.get("count"):
                lines.append(f"| {name} | 0 | — | — | — | — |")
                continue
            lines.append(
                f"| {name} | {h['count']} | {_fmt(h['p50'], 1e3)} | "
                f"{_fmt(h['p95'], 1e3)} | {_fmt(h['p99'], 1e3)} | "
                f"{_fmt(h['max'], 1e3)} |")
    return "\n".join(lines)


def serving_slos(registry: Optional[Registry] = None,
                 attn_impl: Optional[str] = None,
                 n_hosts: Optional[int] = None) -> Dict:
    """The serving SLO trio as flat row fields (ms units, JSON-friendly).

    Pulled from the Server's canonical metric names; absent metrics yield
    ``None`` so bench rows stay diffable across configurations that never
    served (e.g. train-only runs).

    ``attn_impl`` tags which decode-attention engine produced the numbers
    (pass :attr:`Server.attn_impl`); it rides along in the row so
    ``benchmarks/run.py --compare`` never diffs jnp-path SLOs against
    kernel-path SLOs silently.  ``n_hosts`` does the same for fleet runs:
    pass the host count when ``registry`` is a merged fleet view
    (:meth:`repro.telemetry.Registry.merge`), so single-host SLOs are never
    compared against fleet SLOs under one key.
    """
    snap = snapshot(registry)
    hists, gauges = snap["histograms"], snap["gauges"]

    def p50(name):
        h = hists.get(name, {})
        return round(h["p50"] * 1e3, 3) if h.get("count") else None

    occ = gauges.get("server.block_occupancy", {})
    slos = {"ttft_ms": p50("server.ttft_s"),
            "tpot_ms": p50("server.tpot_s"),
            "occupancy_peak": round(occ["hwm"], 3) if occ else None}
    if attn_impl is not None:
        slos["attn_impl"] = attn_impl
    if n_hosts is not None:
        slos["n_hosts"] = n_hosts
    return slos


def merge_into_bench(record: Dict, registry: Optional[Registry] = None
                     ) -> Dict:
    """Attach the telemetry snapshot to a BENCH_imc.json-style record
    (in place; returned for chaining)."""
    record["telemetry"] = snapshot(registry)
    return record
