"""Monotonic-clock span tracing, exported as Chrome trace-event JSON.

``clock()`` is the ONE wall-clock source for the whole runtime
(``time.perf_counter``: monotonic, high-resolution, immune to NTP steps —
``time.time`` is neither).  Every timing site in the Engine/Server/runtime
loops goes through it, so durations are comparable across modules.

Spans are host-side begin/end pairs around interesting regions (AOT lower /
compile, prefill, decode ticks).  They nest naturally — the recorder emits
Chrome "complete" (``ph="X"``) events whose containment on a thread's
timeline encodes the hierarchy — and the JSON loads directly in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``:

    with span("engine.aot.lower", arch=cfg.name):
        lowered = jitted.lower(*specs)
    export_chrome_trace("trace.json")

Like metrics, spans obey the owning :class:`~repro.telemetry.registry
.Registry`'s ``enabled`` flag: disabled, ``span()`` yields without recording
(one branch, no allocation), so hot decode loops can keep their spans.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Dict, List, Optional

from repro.telemetry.registry import Registry, get_registry

__all__ = ["clock", "SpanRecorder", "get_recorder", "span",
           "export_chrome_trace"]

clock = time.perf_counter


class SpanRecorder:
    """Collects complete-span events; one recorder per registry by default."""

    def __init__(self, registry: Optional[Registry] = None):
        self.registry = registry or get_registry()
        self.events: List[Dict] = []
        self._tls = threading.local()

    def _depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    @contextlib.contextmanager
    def span(self, name: str, **args):
        if not self.registry.enabled:
            yield
            return
        self._tls.depth = self._depth() + 1
        t0 = clock()
        try:
            yield
        finally:
            dur = clock() - t0
            self._tls.depth -= 1
            ev = {"name": name, "ph": "X", "cat": "repro",
                  "ts": t0 * 1e6, "dur": dur * 1e6,
                  "pid": 0, "tid": threading.get_ident()}
            if args:
                ev["args"] = {k: str(v) for k, v in args.items()}
            self.events.append(ev)

    def clear(self) -> None:
        self.events.clear()

    def chrome_trace(self) -> Dict:
        """The Chrome trace-event JSON object (Perfetto-loadable)."""
        return {"traceEvents": sorted(self.events, key=lambda e: e["ts"]),
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


_GLOBAL = SpanRecorder()


def get_recorder() -> SpanRecorder:
    """The process-global recorder (paired with the global registry)."""
    return _GLOBAL


def span(name: str, **args):
    """Span on the global recorder: ``with span("server.prefill", bucket=16)``."""
    return _GLOBAL.span(name, **args)


def export_chrome_trace(path: str, recorder: Optional[SpanRecorder] = None
                        ) -> str:
    """Write the recorded spans as Chrome trace-event JSON; returns ``path``."""
    return (recorder or _GLOBAL).export(path)
