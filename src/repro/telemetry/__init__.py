"""Zero-dependency observability for the IMC engine/server stack.

Three small modules, one import surface:

  registry — named Counter/Gauge/Histogram (fixed log-spaced buckets,
             p50/p95/p99 summaries), a process-global Registry, and a
             disabled mode whose record path is a no-op branch.
  spans    — ``clock()`` (the runtime's one monotonic wall-clock source) and
             nested span tracing exported as Perfetto-loadable Chrome
             trace-event JSON.
  export   — explicit JSON / markdown snapshots + BENCH_imc.json merge.

The hard rule every instrumentation site obeys: **recording is host-side
only** — no jax arrays, no device reads, no trace inputs — so telemetry can
never add a host<->device sync or a retrace to a compiled step.  The
zero-steady-state-retrace serving guarantees hold with telemetry enabled
(pinned by tests/test_telemetry.py).
"""
from repro.telemetry.export import (merge_into_bench, serving_slos, snapshot,
                                    to_markdown, write_json)
from repro.telemetry.registry import (Counter, Gauge, Histogram, Registry,
                                      get_registry, set_enabled)
from repro.telemetry.spans import (SpanRecorder, clock, export_chrome_trace,
                                   get_recorder, span)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "get_registry",
    "set_enabled", "SpanRecorder", "clock", "export_chrome_trace",
    "get_recorder", "span", "merge_into_bench", "serving_slos", "snapshot",
    "to_markdown", "write_json",
]
