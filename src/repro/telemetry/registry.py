"""Named metrics: Counter / Gauge / Histogram behind a process-global Registry.

The source paper's headline claims are *measurements* (0.7 ns latency,
56.56 fJ/bit, 15.8 M ops/s); this module is how the serving stack measures its
own analogues — step times, TTFT/TPOT, cache hit rates, block-pool occupancy —
without printf scatter or per-class ad-hoc counters.

Design constraints (they shape everything below):

  * **host-side only** — metrics record plain Python floats the caller already
    has.  Nothing here touches a jax array, so recording can never add a
    host<->device sync or a retrace to a jitted step.
  * **cheap enough for decode loops** — the record path is one attribute load,
    one branch, and a few float ops.  With the registry disabled it is the
    branch alone: ``if not enabled: return`` allocates nothing and touches no
    metric state, so telemetry can stay compiled into hot loops.
  * **fixed log-spaced buckets** — histograms never store samples.  Bucket
    edges are ``10**(i / per_decade)`` spanning ``lo..hi``, so memory is
    constant, merging is addition, and p50/p95/p99 come from bucket
    interpolation with bounded relative error (~``10**(1/per_decade) - 1``).
  * **process-global registry** — one :func:`get_registry` instance by
    default, so the Engine, Server, and runtime loops all land in one
    snapshot; components still accept an explicit :class:`Registry` for
    isolation (benchmarks time separate runs, tests avoid cross-talk).
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Dict, Optional

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "get_registry",
           "set_enabled"]


class Counter:
    """Monotonic count (events, tokens, cache hits)."""

    __slots__ = ("name", "_reg", "value")

    def __init__(self, name: str, reg: "Registry"):
        self.name = name
        self._reg = reg
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if not self._reg.enabled:
            return
        self.value += n

    def zero(self) -> None:
        self.value = 0

    def summary(self) -> int:
        return self.value


class Gauge:
    """Last-write-wins level (queue depth, pool occupancy) + high-water mark."""

    __slots__ = ("name", "_reg", "value", "hwm")

    def __init__(self, name: str, reg: "Registry"):
        self.name = name
        self._reg = reg
        self.value = 0.0
        self.hwm = 0.0

    def set(self, v: float) -> None:
        if not self._reg.enabled:
            return
        self.value = v
        if v > self.hwm:
            self.hwm = v

    def zero(self) -> None:
        self.value = 0.0
        self.hwm = 0.0

    def summary(self) -> Dict[str, float]:
        return {"value": self.value, "hwm": self.hwm}


class Histogram:
    """Fixed log-spaced buckets over ``[lo, hi]`` + count/sum/min/max.

    Built for durations in seconds: the default span 1 µs .. 1000 s at 9
    buckets/decade (81 buckets) estimates percentiles within ~15% relative
    error, which is plenty to tell a 0.9 ms decode step from a 1.3 ms one.
    Values outside the span clamp into the edge buckets (min/max stay exact).
    """

    __slots__ = ("name", "_reg", "lo", "per_decade", "_log_lo", "_nbuckets",
                 "buckets", "count", "sum", "min", "max")

    def __init__(self, name: str, reg: "Registry", *, lo: float = 1e-6,
                 hi: float = 1e3, per_decade: int = 9,
                 nbuckets: Optional[int] = None):
        if lo <= 0 or (nbuckets is None and hi <= lo):
            raise ValueError(f"need 0 < lo < hi, got [{lo}, {hi}]")
        self.name = name
        self._reg = reg
        self.lo = lo
        self.per_decade = per_decade
        self._log_lo = math.log10(lo)
        if nbuckets is None:  # explicit count: exact reconstruction on merge
            decades = math.log10(hi) - self._log_lo
            nbuckets = max(1, math.ceil(decades * per_decade))
        self._nbuckets = nbuckets
        self.zero()

    def zero(self) -> None:
        self.buckets = [0] * self._nbuckets
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        if not self._reg.enabled:
            return
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= self.lo:
            i = 0
        else:
            i = int((math.log10(v) - self._log_lo) * self.per_decade)
            if i >= self._nbuckets:
                i = self._nbuckets - 1
        self.buckets[i] += 1

    def _edges(self, i: int):
        lo = 10.0 ** (self._log_lo + i / self.per_decade)
        hi = 10.0 ** (self._log_lo + (i + 1) / self.per_decade)
        return lo, hi

    def percentile(self, q: float) -> Optional[float]:
        """q in [0, 100]; linear interpolation inside the covering bucket,
        clamped to the exact observed min/max (tight for small samples)."""
        if self.count == 0:
            return None
        target = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.buckets):
            if c == 0:
                continue
            cum += c
            if cum >= target:
                lo, hi = self._edges(i)
                lo, hi = max(lo, self.min), min(hi, self.max)
                frac = (target - (cum - c)) / c
                return lo + frac * (hi - lo)
        return self.max

    def summary(self) -> Dict[str, Optional[float]]:
        if self.count == 0:
            return {"count": 0}
        return {"count": self.count, "sum": self.sum,
                "mean": self.sum / self.count,
                "min": self.min, "max": self.max,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99),
                # bucket state rides along (sparse, JSON-keyed) so fleet
                # merges are EXACT: addition of bucket counts loses nothing.
                "lo": self.lo, "per_decade": self.per_decade,
                "nbuckets": self._nbuckets,
                "buckets": {str(i): c for i, c in enumerate(self.buckets)
                            if c}}


class Registry:
    """Named metric store.  ``enabled=False`` turns every record into a no-op
    branch; creation/lookup still works, so instrumented code needs no guards.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = cls(name, self, **kw)
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get(name, Histogram, **kw)

    # ------------------------------------------------------------- control
    @contextlib.contextmanager
    def disabled(self):
        prev, self.enabled = self.enabled, False
        try:
            yield self
        finally:
            self.enabled = prev

    def reset(self) -> None:
        """Zero every metric IN PLACE (benchmark waves, test isolation).

        Identity-preserving on purpose: instrumented components cache their
        metric handles at construction, so resetting must not orphan them
        from the snapshot.
        """
        with self._lock:
            for m in self._metrics.values():
                m.zero()

    # --------------------------------------------------------------- merge
    @classmethod
    def merge(cls, *snapshots: Dict) -> "Registry":
        """Rebuild ONE registry from many :meth:`snapshot` dicts (fleet view).

        Merge semantics, chosen so a merged registry reads as-if every host
        had fed a single registry:

          * counters — sum (events are events on every host).
          * gauges   — ``value`` sums (levels add across hosts: queue depths,
            tokens/s), ``hwm`` takes the max (the worst single-host pressure;
            a fleet-wide summed high-water would pin moments that never
            co-occurred).
          * histograms — **exact** bucket addition: every snapshot carries
            its sparse bucket counts plus (lo, per_decade, nbuckets), so the
            merged percentiles equal the percentiles of a single histogram
            fed the concatenated samples.  Mismatched bucket layouts under
            one name raise instead of silently blending.

        Identity holds: ``Registry.merge(snap)`` snapshots back to ``snap``.
        """
        reg = cls()
        for snap in snapshots:
            for name, v in snap.get("counters", {}).items():
                reg.counter(name).value += v
            for name, g in snap.get("gauges", {}).items():
                gauge = reg.gauge(name)
                gauge.value += g["value"]
                gauge.hwm = max(gauge.hwm, g["hwm"])
            for name, h in snap.get("histograms", {}).items():
                if not h.get("count"):
                    reg.histogram(name)
                    continue
                hist = reg._get(name, Histogram, lo=h["lo"],
                                per_decade=h["per_decade"],
                                nbuckets=h["nbuckets"])
                layout = (h["lo"], h["per_decade"], h["nbuckets"])
                if hist.count == 0 and \
                        (hist.lo, hist.per_decade, hist._nbuckets) != layout:
                    # an earlier empty snapshot pinned the default layout;
                    # the first populated one is authoritative
                    hist = reg._metrics[name] = Histogram(
                        name, reg, lo=h["lo"], per_decade=h["per_decade"],
                        nbuckets=h["nbuckets"])
                if (hist.lo, hist.per_decade, hist._nbuckets) != layout:
                    raise ValueError(
                        f"histogram {name!r}: bucket layout mismatch across "
                        f"snapshots — cannot merge exactly")
                for i, c in h["buckets"].items():
                    hist.buckets[int(i)] += c
                hist.count += h["count"]
                hist.sum += h["sum"]
                hist.min = min(hist.min, h["min"])
                hist.max = max(hist.max, h["max"])
        return reg

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Dict[str, Dict]:
        """Explicit, pull-based export: {counters, gauges, histograms}.

        Recording never serializes anything; this is the one place metric
        state is read out, so the hot path stays write-only.
        """
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in sorted(self._metrics.items()):
            kind = {Counter: "counters", Gauge: "gauges",
                    Histogram: "histograms"}[type(m)]
            out[kind][name] = m.summary()
        return out


_GLOBAL = Registry()


def get_registry() -> Registry:
    """The process-global registry (the default feed for every component)."""
    return _GLOBAL


def set_enabled(flag: bool) -> None:
    """Flip the global registry's record path on/off."""
    _GLOBAL.enabled = flag
