"""Unified decoder stack: pattern-grouped scan over heterogeneous blocks.

Layers follow ``cfg.pattern`` repeated ``n_groups`` times (+ optional ``tail``)
— e.g. gemma3 = ("local",)*5 + ("global",) x 8 groups; recurrentgemma =
("rglru","rglru","local") x 12 + ("rglru","rglru") tail.  Parameters of each
pattern position are stacked across groups and executed with ``jax.lax.scan``
(fast compiles at 80 layers, natural remat boundary, FSDP-friendly: XLA
all-gathers one group's weights per iteration).

Block kinds:
  attn   — global attention + MLP
  local  — sliding-window attention + MLP
  moe    — global attention + MoE FFN
  rglru  — RG-LRU recurrent block + MLP
  ssd    — Mamba2 SSD block (no MLP)
"""
from __future__ import annotations

import contextlib
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import attn_decode, attn_forward, attn_prefill
from repro.models.common import (fabric_noise_key, fold_fabric_key,
                                 init_rmsnorm, rmsnorm, shard_hint)
from repro.models.mlp import apply_mlp, init_mlp
from repro.models.moe import apply_moe, init_moe
from repro.models.rglru import init_rglru, rglru_decode, rglru_forward
from repro.models.ssd import init_ssd, ssd_decode, ssd_forward


class StackCache(NamedTuple):
    groups: Any  # tuple (per pattern position) of stacked (G, ...) caches
    tail: Any  # tuple (per tail position) of caches
    pos: jnp.ndarray  # scalar int32: next position to decode


# ------------------------------------------------------------------ init
def init_block(key, cfg: ModelConfig, kind: str):
    from repro.models.attention import init_attention

    keys = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"norm1": init_rmsnorm(d)}
    if kind in ("attn", "local", "moe"):
        p["attn"] = init_attention(keys[0], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.hd, qkv_bias=cfg.qkv_bias)
    elif kind == "rglru":
        p["rglru"] = init_rglru(keys[0], d, cfg.lru_w, cfg.conv_width)
    elif kind == "ssd":
        p["ssd"] = init_ssd(keys[0], d, expand=cfg.ssm_expand,
                            headdim=cfg.ssm_headdim, state=cfg.ssm_state,
                            conv_width=cfg.conv_width)
        if cfg.post_norm:
            p["post_norm1"] = init_rmsnorm(d)
        return p
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        p["post_norm1"] = init_rmsnorm(d)
        p["post_norm2"] = init_rmsnorm(d)
    p["norm2"] = init_rmsnorm(d)
    if kind == "moe":
        p["moe"] = init_moe(keys[1], d, cfg.d_ff, cfg.n_experts,
                            cfg.mlp if cfg.mlp != "none" else "swiglu")
    elif cfg.mlp != "none":
        p["mlp"] = init_mlp(keys[1], d, cfg.d_ff, cfg.mlp)
    return p


def init_stack(key, cfg: ModelConfig):
    """Stacked params: {"groups": tuple per position (leading dim G),
    "tail": tuple per tail position}."""
    g = cfg.n_groups_layers
    kg, kt = jax.random.split(key)
    groups = []
    for p_idx, kind in enumerate(cfg.pattern):
        pk = jax.random.fold_in(kg, p_idx)
        keys = jax.random.split(pk, g)
        groups.append(jax.vmap(lambda k, kd=kind: init_block(k, cfg, kd))(keys))
    tail = []
    for p_idx, kind in enumerate(cfg.tail):
        tail.append(init_block(jax.random.fold_in(kt, p_idx), cfg, kind))
    return {"groups": tuple(groups), "tail": tuple(tail)}


# ------------------------------------------------------------------ blocks
def _imc_kw(cfg: ModelConfig):
    """Fabric routing for every projection in the stack: ONE typed spec."""
    spec = cfg.imc_fabric
    if spec is None:
        return {}
    return {"spec": spec}


def _mix(cfg, params, x, kind, mode, cache, pos, prefill_extra=0,
         true_len=None, block_table=None):
    """The token-mixing half of a block. Returns (y, new_cache)."""
    imc = _imc_kw(cfg)
    window = cfg.window if kind == "local" else 0
    if kind in ("attn", "local", "moe"):
        kw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                  head_dim=cfg.hd, rope_theta=cfg.rope_theta, window=window,
                  **imc)
        if mode == "train":
            return attn_forward(params["attn"], x, q_chunk=cfg.q_chunk,
                                chunk_remat=cfg.chunk_remat,
                                native_dtype_dots=cfg.native_dtype_dots,
                                use_flash=cfg.use_flash_kernel,
                                **kw), None
        if mode == "prefill":
            if true_len is not None:
                # Ragged (right-padded) admission prefill: keep EVERY row,
                # even for windowed layers — a window-sized ring over the
                # padded sequence could evict valid positions.  The cache is
                # ephemeral here (scattered into the paged pools), so the
                # full-length allocation lives only for one admit.
                cache_len = x.shape[1]
            else:
                cache_len = window if window else x.shape[1] + prefill_extra
            return attn_prefill(params["attn"], x, q_chunk=cfg.q_chunk,
                                cache_len=cache_len, kv_dtype=cfg.kv_dtype,
                                true_len=true_len,
                                use_flash=cfg.use_flash_kernel, **kw)
        return attn_decode(params["attn"], x, cache, pos,
                           block_table=block_table,
                           attn_impl=cfg.attn_impl, **kw)
    if kind == "rglru":
        if mode in ("train", "prefill"):
            y, (h, cs) = rglru_forward(params["rglru"], x, **imc)
            return y, ((h, cs) if mode == "prefill" else None)
        h, cs = cache
        y, (h, cs) = rglru_decode(params["rglru"], x, h, cs, **imc)
        return y, (h, cs)
    if kind == "ssd":
        kw = dict(expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
                  state=cfg.ssm_state, **imc)
        if mode in ("train", "prefill"):
            y, c = ssd_forward(params["ssd"], x, chunk=cfg.ssd_chunk, **kw)
            return y, (c if mode == "prefill" else None)
        return ssd_decode(params["ssd"], x, cache, **kw)
    raise ValueError(kind)


def apply_block(params, x, kind: str, cfg: ModelConfig, mode: str,
                cache=None, pos=None, prefill_extra=0, true_len=None,
                block_table=None):
    """Pre-norm residual block. Returns (x, new_cache, aux)."""
    aux = {}
    h = rmsnorm(params["norm1"], x)
    y, new_cache = _mix(cfg, params, h, kind, mode, cache, pos,
                        prefill_extra=prefill_extra, true_len=true_len,
                        block_table=block_table)
    if cfg.post_norm:
        y = rmsnorm(params["post_norm1"], y)
    x = x + y
    x = shard_hint(x, "residual")
    if kind == "ssd":
        return x, new_cache, aux
    h = rmsnorm(params["norm2"], x)
    if kind == "moe":
        y, aux = apply_moe(params["moe"], h, n_experts=cfg.n_experts,
                           top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           kind=cfg.mlp if cfg.mlp != "none" else "swiglu",
                           combine_dtype=(jnp.float32
                                          if cfg.moe_combine_dtype == "f32"
                                          else jnp.bfloat16),
                           **_imc_kw(cfg))
    else:
        y = apply_mlp(params["mlp"], h, cfg.mlp, **_imc_kw(cfg))
    if cfg.post_norm:
        y = rmsnorm(params["post_norm2"], y)
    x = x + y
    x = shard_hint(x, "residual")
    return x, new_cache, aux


# ------------------------------------------------------------------ stack
def _zero_aux():
    return {"load_balance_loss": jnp.float32(0.0),
            "router_z_loss": jnp.float32(0.0)}


def _acc_aux(acc, aux):
    if not aux:
        return acc
    return {k: acc[k] + aux[k] for k in acc}


def stack_forward(params, x, cfg: ModelConfig, mode: str,
                  cache: Optional[StackCache] = None, pos=None,
                  prefill_extra: int = 0, true_len=None, block_table=None):
    """Run the full stack. Returns (x, new_cache | None, aux).

    ``true_len`` (prefill, traced scalar): the prompt occupies positions
    ``[0, true_len)`` of a right-padded ``x`` — caches mark the padded tail
    empty and ``pos`` lands on ``true_len``.  ``block_table`` (decode,
    (B, max_blocks) int32): routes attention through paged KV pools when the
    cache holds :class:`~repro.models.attention.PagedAttnCache` leaves.
    """
    assert mode in ("train", "prefill", "decode")
    build_cache = mode in ("prefill", "decode")

    # Noisy fabric: one ambient fold per forward, split per layer group and
    # carried through the scan xs — groups share ONE traced body, so without
    # this every group would replay the same trace-time noise stream.
    spec = cfg.imc_fabric
    gkeys = None
    if spec is not None and spec.noisy:
        base = fold_fabric_key()
        if base is not None:
            gkeys = jax.random.split(base, cfg.n_groups_layers)

    def group_body(carry, xs):
        x, aux_acc = carry
        gparams = xs[0]
        gcaches = xs[1] if mode == "decode" else (None,) * len(cfg.pattern)
        ctx = (fabric_noise_key(xs[-1]) if gkeys is not None
               else contextlib.nullcontext())
        new_caches = []
        with ctx:
            for p_idx, kind in enumerate(cfg.pattern):
                x, nc, aux = apply_block(gparams[p_idx], x, kind, cfg, mode,
                                         cache=gcaches[p_idx], pos=pos,
                                         prefill_extra=prefill_extra,
                                         true_len=true_len,
                                         block_table=block_table)
                new_caches.append(nc)
        ys = tuple(new_caches) if build_cache else None
        return (x, _acc_aux(aux_acc, aux)), ys

    body = jax.checkpoint(group_body) if (cfg.remat and mode == "train") \
        else group_body
    xs = (params["groups"],)
    if mode == "decode":
        xs = (params["groups"], cache.groups)
    if gkeys is not None:
        xs = xs + (gkeys,)
    (x, aux_acc), group_caches = jax.lax.scan(body, (x, _zero_aux()), xs)

    tail_caches = []
    for p_idx, kind in enumerate(cfg.tail):
        tc = cache.tail[p_idx] if mode == "decode" else None
        x, nc, aux = apply_block(params["tail"][p_idx], x, kind, cfg, mode,
                                 cache=tc, pos=pos,
                                 prefill_extra=prefill_extra,
                                 true_len=true_len, block_table=block_table)
        aux_acc = _acc_aux(aux_acc, aux)
        tail_caches.append(nc)

    new_cache = None
    if build_cache:
        new_pos = (pos + 1) if mode == "decode" else None
        if mode == "prefill":
            new_pos = jnp.asarray(
                x.shape[1] if true_len is None else true_len, jnp.int32)
        new_cache = StackCache(group_caches, tuple(tail_caches), new_pos)
    return x, new_cache, aux_acc
