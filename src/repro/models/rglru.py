"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block = conv1d (width 4) -> real-gated linear recurrent unit, flanked by an
input GeLU gate branch (the "recurrent block" of arXiv:2402.19427):

    r_t = sigmoid(W_a x_t)                    (recurrence gate)
    i_t = sigmoid(W_x x_t)                    (input gate)
    a_t = exp(c * softplus(L) * (-r_t))       (log-space stable; c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` (log-depth parallel scan, the
TPU-friendly formulation); decode is the O(1) recurrence step with carried h.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense, init_dense

_C = 8.0


def init_rglru(key, d_model: int, width: int, conv_width: int = 4,
               dtype=jnp.bfloat16):
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    return {
        "w_gate_branch": init_dense(k1, d_model, width, dtype=dtype),
        "w_x_branch": init_dense(k2, d_model, width, dtype=dtype),
        "conv_w": (jax.random.normal(k3, (conv_width, width), jnp.float32)
                   * conv_width ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "w_a": init_dense(k4, width, width, bias=True, dtype=dtype),
        "w_i": init_dense(k5, width, width, bias=True, dtype=dtype),
        "lam": jnp.asarray(
            jax.random.uniform(k6, (width,), jnp.float32, 1.0, 4.0)),
        "w_out": init_dense(k7, width, d_model, dtype=dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,W); w: (cw, W). state: (B, cw-1, W)."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(cw))
    new_state = xp[:, xp.shape[1] - (cw - 1):]
    return out + b[None, None], new_state


def _gates(params, xc):
    r = jax.nn.sigmoid(dense(params["w_a"], xc).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(params["w_i"], xc).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r  # (..., W) f32, <= 0
    a = jnp.exp(log_a)
    gated_x = i * xc.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated_x
    return a, b


def rglru_forward(params, x, *, h0=None, conv_state=None, **imc):
    """Full-sequence forward. x: (B,S,D) -> (y, (h_last, conv_state))."""
    gate = jax.nn.gelu(dense(params["w_gate_branch"], x, **imc))
    xb = dense(params["w_x_branch"], x, **imc)
    xc, conv_state = _causal_conv(xb, params["conv_w"], params["conv_b"],
                                  conv_state)
    a, b = _gates(params, xc)
    if h0 is not None:
        # fold the carried state in as a virtual step: h_t includes a-prefix * h0
        b = b.at[:, 0].add(a[:, 0] * h0)

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    y = dense(params["w_out"], (h.astype(x.dtype) * gate), **imc)
    return y, (h[:, -1], conv_state)


def rglru_decode(params, x, h, conv_state, **imc):
    """One-step decode. x: (B,1,D); h: (B,W) f32; conv_state: (B,cw-1,W)."""
    gate = jax.nn.gelu(dense(params["w_gate_branch"], x, **imc))
    xb = dense(params["w_x_branch"], x, **imc)
    xc, conv_state = _causal_conv(xb, params["conv_w"], params["conv_b"],
                                  conv_state)
    a, b = _gates(params, xc)  # (B,1,W)
    h = a[:, 0] * h + b[:, 0]
    y = dense(params["w_out"], (h[:, None].astype(x.dtype) * gate), **imc)
    return y, (h, conv_state)
