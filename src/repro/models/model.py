"""LM wrapper: embeddings / modality frontends, stack, head, losses, steps.

Public API:
  init_params(key, cfg)                     -> params pytree
  loss_fn(params, batch, cfg)               -> (loss, metrics)
  forward_logits(params, batch, cfg)        -> logits (small models/examples)
  prefill(params, batch, cfg)               -> (last_logits, StackCache)
  decode_step(params, cache, token, cfg)    -> (logits, StackCache)

Batches:
  token LMs:       {"tokens": (B,S) int32, "labels": (B,S) int32}
  audio/vlm stubs: {"embeddings": (B,S,Fd) bf16, "labels": (B,S) int32}
  decode:          {"token": (B,1) int32} (+ cache)

Cross-entropy is computed in sequence chunks with rematerialization so the
(B,S,V) logits tensor never exists at once (V up to 262k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense, init_dense, init_rmsnorm, rmsnorm, shard_hint
from repro.models.transformer import StackCache, init_stack, stack_forward

AUX_LB_COEF = 0.01
AUX_Z_COEF = 0.001
CE_CHUNK = 512


# -------------------------------------------------------------------- init
def init_params(key, cfg: ModelConfig):
    k_emb, k_stack, k_head, k_front = jax.random.split(key, 4)
    params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(jnp.bfloat16),
        "blocks": init_stack(k_stack, cfg),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if cfg.frontend != "none":
        params["frontend_proj"] = init_dense(k_front, cfg.frontend_dim,
                                             cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(k_head, cfg.d_model, cfg.vocab_size,
                                       scale=cfg.d_model ** -0.5)
    return params


def _head_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]["w"]


def _embed_inputs(params, batch, cfg: ModelConfig):
    if cfg.frontend != "none":
        x = dense(params["frontend_proj"], batch["embeddings"].astype(jnp.bfloat16))
    else:
        x = params["embed"][batch["tokens"]]
    return shard_hint(x, "residual")


# -------------------------------------------------------------------- loss
def _chunked_ce(x, head_w, labels, chunk: int = CE_CHUNK):
    """Mean token CE without materializing full (B,S,V) logits."""
    b, s, d = x.shape
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(tot, args):
        xi, li = args
        logits = (xi @ head_w.astype(xi.dtype)).astype(jnp.float32)
        logits = shard_hint(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, lc))
    return tot / (b * s)


def loss_fn(params, batch, cfg: ModelConfig):
    x = _embed_inputs(params, batch, cfg)
    x, _, aux = stack_forward(params["blocks"], x, cfg, "train")
    x = rmsnorm(params["final_norm"], x)
    ce = _chunked_ce(x, _head_weight(params, cfg), batch["labels"])
    loss = ce
    metrics = {"ce": ce}
    if cfg.n_experts:
        loss = (loss + AUX_LB_COEF * aux["load_balance_loss"]
                + AUX_Z_COEF * aux["router_z_loss"])
        metrics.update(aux)
    metrics["loss"] = loss
    return loss, metrics


def forward_logits(params, batch, cfg: ModelConfig):
    """Full logits — small models only (examples / tests)."""
    x = _embed_inputs(params, batch, cfg)
    x, _, _ = stack_forward(params["blocks"], x, cfg, "train")
    x = rmsnorm(params["final_norm"], x)
    return (x @ _head_weight(params, cfg).astype(x.dtype)).astype(jnp.float32)


# ------------------------------------------------------------------ serving
def prefill(params, batch, cfg: ModelConfig, max_new_tokens: int = 0):
    """batch: {"tokens": (B,S)} (+ optional "length": () int32 true prompt
    length for a right-padded bucket — the last-token logits then come from
    position ``length - 1`` and the cache marks the padded tail empty, so
    one executable per bucket size serves every shorter prompt).
    """
    length = batch.get("length")
    x = _embed_inputs(params, batch, cfg)
    x, cache, _ = stack_forward(params["blocks"], x, cfg, "prefill",
                                prefill_extra=max_new_tokens,
                                true_len=length)
    if length is None:
        x_last = x[:, -1:]
    else:
        x_last = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(length, jnp.int32) - 1, 1, axis=1)
    x_last = rmsnorm(params["final_norm"], x_last)
    logits = (x_last @ _head_weight(params, cfg).astype(x_last.dtype))
    return logits[:, 0].astype(jnp.float32), cache


def decode_step(params, cache: StackCache, token, cfg: ModelConfig,
                block_table=None):
    """token: (B, 1) int32. Returns (logits (B,V) f32, new cache).

    ``block_table`` ((B, max_blocks) int32) routes attention through paged
    KV pools when ``cache`` carries them (see models/kv_cache.py).
    """
    x = params["embed"][token]
    x = shard_hint(x, "residual")
    x, new_cache, _ = stack_forward(params["blocks"], x, cfg, "decode",
                                    cache=cache, pos=cache.pos,
                                    block_table=block_table)
    x = rmsnorm(params["final_norm"], x)
    logits = (x @ _head_weight(params, cfg).astype(x.dtype))
    return logits[:, 0].astype(jnp.float32), new_cache
