"""Mamba2 SSD (state-space duality) block — chunked parallel form + O(1) decode.

Selective SSM with scalar-per-head decay (arXiv:2405.21060):
    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t x_t^T        (P x N state/head)
    y_t = C_t . h_t + D_h * x_t
Chunked algorithm: intra-chunk quadratic term (attention-like, MXU-friendly)
+ inter-chunk state recurrence (scan over S/chunk steps).  The block wraps the
SSM with in_proj -> causal conv -> SiLU, a SiLU(z) gate, gated RMSNorm, and
out_proj, matching the Mamba2 macro-block.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense, init_dense, init_rmsnorm, rmsnorm


class SsdCache(NamedTuple):
    conv_state: jnp.ndarray  # (B, cw-1, conv_channels)
    ssm_state: jnp.ndarray  # (B, H, P, N) float32


def init_ssd(key, d_model: int, *, expand: int = 2, headdim: int = 64,
             state: int = 128, n_groups: int = 1, conv_width: int = 4,
             dtype=jnp.bfloat16):
    d_inner = expand * d_model
    heads = d_inner // headdim
    conv_ch = d_inner + 2 * n_groups * state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": init_dense(k1, d_model,
                              2 * d_inner + 2 * n_groups * state + heads,
                              dtype=dtype),
        "conv_w": (jax.random.normal(k2, (conv_width, conv_ch), jnp.float32)
                   * conv_width ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jax.random.uniform(k3, (heads,), jnp.float32, 1.0, 16.0)),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "norm": init_rmsnorm(d_inner, jnp.float32),
        "out_proj": init_dense(k4, d_inner, d_model, dtype=dtype),
    }


def _split_proj(proj, d_inner, n_groups, state, heads):
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt_raw = jnp.split(xbc_dt, [d_inner + 2 * n_groups * state], axis=-1)
    return z, xbc, dt_raw


def _causal_conv(x, w, b, state=None):
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(cw))
    return out + b[None, None], xp[:, xp.shape[1] - (cw - 1):]


def _ssd_chunked(x, dt, a_neg, B, C, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (Bt,S,H,P); dt: (Bt,S,H) >0; a_neg: (H,) <0; B,C: (Bt,S,G,N).
    Returns y (Bt,S,H,P), h_last (Bt,H,P,N) float32.
    """
    bt, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    if s % chunk != 0:
        chunk = s
    nc = s // chunk

    xc = x.reshape(bt, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(bt, nc, chunk, h)
    # per-head B/C (expand groups)
    Bh = jnp.repeat(B.reshape(bt, nc, chunk, g, n), rep, axis=3).astype(jnp.float32)
    Ch = jnp.repeat(C.reshape(bt, nc, chunk, g, n), rep, axis=3).astype(jnp.float32)

    a = dtc * a_neg[None, None, None, :]  # (bt,nc,chunk,h) <= 0
    cum = jnp.cumsum(a, axis=2)

    # ---- intra-chunk (quadratic, MXU): M[b,c,h,i,j] = CB * exp(cum_i - cum_j) * dt_j, i>=j
    cb = jnp.einsum("bcihn,bcjhn->bchij", Ch, Bh)
    cum_t = cum.transpose(0, 1, 3, 2)  # (bt,nc,h,chunk)
    # decay[b,c,h,i,j] = exp(cum[b,c,i,h] - cum[b,c,j,h]), i >= j.
    # Mask the EXPONENT (not the product): exp of the i<j entries overflows,
    # and inf*0 would poison the backward pass with NaNs.
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, None]
    diff = jnp.where(causal,
                     cum_t[:, :, :, :, None] - cum_t[:, :, :, None, :],
                     -jnp.inf)
    decay = jnp.exp(diff)
    m = cb * decay * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", m, xc)

    # ---- chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    sdec = jnp.exp(cum[:, :, -1:, :] - cum)  # (bt,nc,chunk,h)
    s_chunk = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", sdec * dtc, Bh, xc)

    # ---- inter-chunk recurrence over nc chunks
    cdec = jnp.exp(cum[:, :, -1, :])  # (bt,nc,h)
    h_init = (jnp.zeros((bt, h, p, n), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def step(hprev, inp):
        dec, sc = inp  # (bt,h), (bt,h,p,n)
        return dec[..., None, None] * hprev + sc, hprev

    h_last, h_prevs = jax.lax.scan(
        step, h_init,
        (cdec.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # (bt,nc,h,p,n)

    y_inter = jnp.einsum("bcihn,bchpn,bcih->bcihp", Ch, h_prevs,
                         jnp.exp(cum))
    y = (y_intra + y_inter).reshape(bt, s, h, p)
    return y, h_last


def ssd_forward(params, x, *, expand: int = 2, headdim: int = 64,
                state: int = 128, n_groups: int = 1, chunk: int = 128,
                cache: SsdCache | None = None, **imc):
    """Full-sequence forward. x: (B,S,D) -> (y, SsdCache)."""
    bt, s, d = x.shape
    d_inner = expand * d
    heads = d_inner // headdim
    proj = dense(params["in_proj"], x, **imc)
    z, xbc, dt_raw = _split_proj(proj, d_inner, n_groups, state, heads)
    conv_in_state = cache.conv_state if cache is not None else None
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                   conv_in_state)
    xbc = jax.nn.silu(xbc)
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + n_groups * state], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a_neg = -jnp.exp(params["a_log"])
    y, h_last = _ssd_chunked(
        xs.reshape(bt, s, heads, headdim), dt, a_neg,
        B.reshape(bt, s, n_groups, state), C.reshape(bt, s, n_groups, state),
        chunk, h0=cache.ssm_state if cache is not None else None)
    y = y + params["d_skip"][None, None, :, None] * xs.reshape(
        bt, s, heads, headdim).astype(jnp.float32)
    y = y.reshape(bt, s, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)))
    out = dense(params["out_proj"], y.astype(x.dtype), **imc)
    return out, SsdCache(conv_state, h_last)


def ssd_decode(params, x, cache: SsdCache, *, expand: int = 2,
               headdim: int = 64, state: int = 128, n_groups: int = 1, **imc):
    """One-token decode. x: (B,1,D)."""
    bt, _, d = x.shape
    d_inner = expand * d
    heads = d_inner // headdim
    proj = dense(params["in_proj"], x, **imc)
    z, xbc, dt_raw = _split_proj(proj, d_inner, n_groups, state, heads)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                   cache.conv_state)
    xbc = jax.nn.silu(xbc)
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + n_groups * state], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]
    a_neg = -jnp.exp(params["a_log"])
    xh = xs.reshape(bt, heads, headdim).astype(jnp.float32)
    rep = heads // n_groups
    Bh = jnp.repeat(B.reshape(bt, n_groups, state), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C.reshape(bt, n_groups, state), rep, axis=1).astype(jnp.float32)
    dec = jnp.exp(dt * a_neg[None])  # (B,H)
    h = (dec[..., None, None] * cache.ssm_state
         + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, xh))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h) + params["d_skip"][None, :, None] * xh
    y = y.reshape(bt, 1, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)))
    out = dense(params["out_proj"], y.astype(x.dtype), **imc)
    return out, SsdCache(conv_state, h)
