"""Paged KV cache: fixed-size blocks, a free-list allocator, block tables.

The serving problem this solves: the pre-paging server (today's
``Server(kv="ring")``) gave every slot one fixed-length ring of
``prompt_len + max_new`` K/V rows, so heterogeneous traffic paid worst-case
memory per slot and a single shared ``prompt_len``.
Paging decouples *logical* sequence length from *physical* cache geometry —
the same move the reconfigurable IMC macros make for array geometry: a pool
of ``num_blocks`` fixed-size blocks per attention layer is shared by all
slots, and a per-slot **block table** maps logical block ``j`` (positions
``[j*block_size, (j+1)*block_size)``) to a physical block id.

Three pieces live here:

  * :class:`BlockAllocator` — host-side free-list bookkeeping with
    ``alloc`` / ``append`` / ``release`` per slot, worst-case *reservations*
    so admission can guarantee a request will never run dry mid-decode, and
    :meth:`check` invariants (every block owned by at most one slot; tables
    are dense prefixes).
  * :class:`PagedAttnCache` — the device-side pool for one attention layer:
    ``k``/``v`` of shape ``(num_blocks, block_size, KV, hd)`` (plus int8
    scale pools), indexed by the block table at decode time.
  * pure pytree surgery — :func:`init_paged_cache` builds an empty paged
    :class:`~repro.models.transformer.StackCache` from one request's ring
    cache, and :func:`merge_prefill_cache` scatters a freshly prefilled
    (B=1, possibly padded) ring cache into the pools at the positions its
    ``key_pos`` names.  Both are jit-friendly (the slot index and block
    table ride as traced arguments, so steady-state admission never
    retraces).

The ring path in :mod:`repro.models.attention` remains the oracle: paged
decode is asserted bit-identical to it in ``tests/test_paged_kv.py``.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import AttnCache, PagedAttnCache

__all__ = [
    "BlockAllocator", "OutOfBlocks", "PagedAttnCache",
    "init_paged_cache", "merge_prefill_cache", "set_slot", "broadcast_slots",
]


class OutOfBlocks(RuntimeError):
    """The free list (minus outstanding reservations) cannot cover a request."""


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` KV blocks with per-slot tables.

    ``alloc(slot, n, reserve=m)`` hands ``n`` physical blocks to ``slot`` now
    and *reserves* ``m`` more from the shared budget (admission control: a
    request that may grow to ``n+m`` blocks is admitted only if all of them
    are guaranteed).  ``append(slot)`` materializes one block — drawing from
    the slot's reservation first — when decode crosses a block boundary.
    ``release(slot)`` returns everything to the free list (early, when a
    request finishes before its ``max_new_tokens`` budget).
    """

    def __init__(self, num_blocks: int, block_size: int, slots: int,
                 max_blocks_per_slot: Optional[int] = None):
        if num_blocks < 1 or block_size < 1 or slots < 1:
            raise ValueError(
                f"invalid paged geometry: {num_blocks} blocks x "
                f"{block_size} tokens, {slots} slots")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.slots = slots
        self.max_blocks_per_slot = max_blocks_per_slot or num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._tables: List[List[int]] = [[] for _ in range(slots)]
        self._reserved: List[int] = [0] * slots

    # ------------------------------------------------------------- queries
    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` KV rows."""
        return -(-n_tokens // self.block_size)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def available(self) -> int:
        """Free blocks not promised to anyone (the admission budget)."""
        return len(self._free) - sum(self._reserved)

    def can_admit(self, n_blocks: int) -> bool:
        return n_blocks <= self.available

    def slot_blocks(self, slot: int) -> List[int]:
        return list(self._tables[slot])

    # ------------------------------------------------------------ mutation
    def alloc(self, slot: int, n: int, reserve: int = 0) -> List[int]:
        """Assign ``n`` blocks to ``slot`` and reserve ``reserve`` more."""
        if len(self._tables[slot]) + self._reserved[slot] + n + reserve \
                > self.max_blocks_per_slot:
            raise OutOfBlocks(
                f"slot {slot}: {n}+{reserve} blocks exceed the per-slot "
                f"table width {self.max_blocks_per_slot}")
        if n + reserve > self.available:
            raise OutOfBlocks(
                f"need {n}+{reserve} blocks, only {self.available} of "
                f"{self.num_blocks} available (free={self.num_free}, "
                f"reserved={sum(self._reserved)})")
        got = [self._free.pop() for _ in range(n)]
        self._tables[slot].extend(got)
        self._reserved[slot] += reserve
        return got

    def append(self, slot: int) -> int:
        """One more block for ``slot`` (reservation-first, else free budget)."""
        if len(self._tables[slot]) >= self.max_blocks_per_slot:
            raise OutOfBlocks(f"slot {slot}: block table full "
                              f"({self.max_blocks_per_slot})")
        if self._reserved[slot] > 0:
            self._reserved[slot] -= 1
        elif self.available < 1:
            raise OutOfBlocks(f"slot {slot}: free list dry on append")
        blk = self._free.pop()
        self._tables[slot].append(blk)
        return blk

    def release(self, slot: int) -> List[int]:
        """Return all of ``slot``'s blocks (and reservation) to the pool."""
        blks = self._tables[slot]
        self._free.extend(blks)
        self._tables[slot] = []
        self._reserved[slot] = 0
        return blks

    # ----------------------------------------------------------- the table
    def table(self) -> np.ndarray:
        """(slots, max_blocks_per_slot) int32 block table; -1 = empty."""
        t = np.full((self.slots, self.max_blocks_per_slot), -1, np.int32)
        for s, blks in enumerate(self._tables):
            t[s, :len(blks)] = blks
        return t

    def table_row(self, slot: int) -> np.ndarray:
        return self.table()[slot]

    def check(self) -> None:
        """Assert the allocator invariants (tests and chaos drills call this).

        * partition: free list + all slot tables = exactly ``num_blocks``
          distinct ids — no block is double-assigned or leaked;
        * tables are dense prefixes (block ``j`` of a slot covers logical
          positions ``[j*bs, (j+1)*bs)`` — compaction is never needed);
        * reservations are non-negative and covered by the free list.
        """
        owned = [b for t in self._tables for b in t]
        allb = self._free + owned
        assert len(set(owned)) == len(owned), "block double-assigned"
        assert sorted(allb) == list(range(self.num_blocks)), \
            "free+assigned is not a partition of the pool"
        for s, t in enumerate(self._tables):
            assert len(t) <= self.max_blocks_per_slot, f"slot {s} overfull"
        assert all(r >= 0 for r in self._reserved), "negative reservation"
        assert sum(self._reserved) <= len(self._free), \
            "reservations exceed the free list"


# ------------------------------------------------------------ device caches
def _cache_entry_leaf(x) -> bool:
    return isinstance(x, (AttnCache, PagedAttnCache))


def _batch_axis(one) -> int:
    """Batch axis of a B=1 cache leaf: grouped leaves are (G, 1, ...) ->
    axis 1; tail leaves are (1, ...) -> axis 0 (pos scalars handled upstream).
    """
    return 1 if one.ndim >= 2 and one.shape[1] == 1 else 0


def broadcast_slots(one, slots: int):
    """Zero-filled batch leaf with ``slots`` rows, shaped after a B=1 leaf."""
    if one.ndim == 0:  # scalar pos -> per-slot position vector
        return jnp.zeros((slots,), one.dtype)
    axis = _batch_axis(one)
    reps = [1] * one.ndim
    reps[axis] = slots
    return jnp.tile(jnp.zeros_like(one), reps)


def set_slot(b, o, slot):
    """Write one request's B=1 cache leaf into the batch cache at ``slot``.

    ``slot`` may be a traced scalar: scalars route through ``.at[slot]`` and
    arrays through ``dynamic_update_slice``, so admission jit-compiles once.
    """
    if b.ndim == 0:
        return b
    if o.ndim == 0:
        return b.at[slot].set(o.astype(b.dtype))
    return jax.lax.dynamic_update_slice_in_dim(
        b, o.astype(b.dtype), slot, axis=_batch_axis(o))


def _empty_pool_like(one: AttnCache, num_blocks: int,
                     block_size: int) -> PagedAttnCache:
    """Zeroed paged pools shaped after one ring cache leaf (keeps the group
    axis and KV/hd geometry; drops the per-slot time axis)."""

    def pool(ring, tail_dims):
        # ring k/v: (..., 1, T, KV, hd) tail_dims=2; scales: (..., 1, T, KV)
        # tail_dims=1.  Drop the (1, T) per-slot window, keep any group axis.
        lead = ring.shape[:-(2 + tail_dims)]
        shape = lead + (num_blocks, block_size) + ring.shape[-tail_dims:]
        return jnp.zeros(shape, ring.dtype)

    return PagedAttnCache(
        k=pool(one.k, 2), v=pool(one.v, 2),
        k_scale=None if one.k_scale is None else pool(one.k_scale, 1),
        v_scale=None if one.v_scale is None else pool(one.v_scale, 1))


def init_paged_cache(one, slots: int, num_blocks: int, block_size: int):
    """Empty batched paged cache shaped after one request's ring StackCache.

    Attention leaves become shared :class:`PagedAttnCache` pools; recurrent
    and conv states stay dense per-slot tensors (they are O(1) in sequence
    length, so paging buys nothing there); ``pos`` becomes a per-slot vector.
    """
    from repro.models.transformer import StackCache

    def build(entry):
        if isinstance(entry, AttnCache):
            return _empty_pool_like(entry, num_blocks, block_size)
        return jax.tree.map(lambda o: broadcast_slots(o, slots), entry)

    groups = jax.tree.map(build, one.groups, is_leaf=_cache_entry_leaf)
    tail = jax.tree.map(build, one.tail, is_leaf=_cache_entry_leaf)
    return StackCache(groups, tail, jnp.zeros((slots,), jnp.int32))


def _scatter_ring(pool: PagedAttnCache, ring: AttnCache,
                  table_row) -> PagedAttnCache:
    """Scatter a (B=1) ring cache's valid rows into the paged pools.

    Destination of ring row ``j`` is named by its own ``key_pos[j]`` (the
    ring's source of truth): position ``p`` lands at flat pool row
    ``table_row[p // bs] * bs + p % bs``.  Invalid rows (``key_pos == -1``,
    e.g. the padded tail of a bucketed ragged prefill) and rows whose logical
    block is unallocated map out of bounds and are dropped.
    """
    nb, bs = pool.k.shape[-4], pool.k.shape[-3]
    kp = ring.key_pos  # (..., 1, T)
    tbl = jnp.where(table_row < 0, nb, table_row)  # OOB sentinel
    blk = tbl[jnp.clip(kp, 0, None) // bs]  # (..., 1, T)
    dest = jnp.where(kp >= 0, blk * bs + kp % bs, nb * bs)  # (..., 1, T)
    idx = jnp.squeeze(dest, axis=-2)  # drop the B=1 axis -> (..., T)

    def scat(pool_arr, ring_arr, tail_dims):
        # pool (..., NB, bs, *tail); ring (..., 1, T, *tail); idx (..., T)
        flat = pool_arr.reshape(pool_arr.shape[:-(2 + tail_dims)] + (nb * bs,)
                                + pool_arr.shape[-tail_dims:])
        src = jnp.squeeze(ring_arr, axis=-(2 + tail_dims))  # (..., T, *tail)
        if flat.ndim == 1 + tail_dims:  # tail leaf: (NB*bs, *tail)
            out = flat.at[idx].set(src.astype(flat.dtype), mode="drop")
        else:  # grouped leaf: (G, NB*bs, *tail) with idx (G, T)
            out = jax.vmap(
                lambda f, i, s: f.at[i].set(s.astype(f.dtype), mode="drop")
            )(flat, idx, src)
        return out.reshape(pool_arr.shape)

    return PagedAttnCache(
        k=scat(pool.k, ring.k, 2), v=scat(pool.v, ring.v, 2),
        k_scale=(None if pool.k_scale is None
                 else scat(pool.k_scale, ring.k_scale, 1)),
        v_scale=(None if pool.v_scale is None
                 else scat(pool.v_scale, ring.v_scale, 1)))


def merge_prefill_cache(batch, one, table_row, slot):
    """Merge one request's freshly prefilled (B=1) ring cache into the batch.

    Pure function of (batch paged cache, ring cache, (max_blocks,) block
    table row, slot index) — jit it once and admission is data-only:
    attention leaves scatter into the shared pools via the table row,
    recurrent/conv states and the per-slot ``pos`` write at ``slot``.
    """
    from repro.models.transformer import StackCache

    def merge(b, o):
        if isinstance(b, PagedAttnCache):
            return _scatter_ring(b, o, table_row)
        return jax.tree.map(lambda bb, oo: set_slot(bb, oo, slot), b, o)

    groups = jax.tree.map(merge, batch.groups, one.groups,
                          is_leaf=_cache_entry_leaf)
    tail = jax.tree.map(merge, batch.tail, one.tail,
                        is_leaf=_cache_entry_leaf)
    pos = batch.pos.at[slot].set(one.pos.astype(batch.pos.dtype))
    return StackCache(groups, tail, pos)
