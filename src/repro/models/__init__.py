from repro.models.model import (decode_step, forward_logits, init_params,
                                loss_fn, prefill)
from repro.models.transformer import StackCache, init_stack, stack_forward
