"""Feed-forward blocks: SwiGLU / GEGLU / GELU, optionally IMC-executed."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense, init_dense, shard_hint


def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"w_gate": init_dense(k1, d_model, d_ff, dtype=dtype),
                "w_up": init_dense(k2, d_model, d_ff, dtype=dtype),
                "w_down": init_dense(k3, d_ff, d_model, dtype=dtype)}
    if kind == "gelu":
        return {"w_up": init_dense(k1, d_model, d_ff, dtype=dtype),
                "w_down": init_dense(k2, d_ff, d_model, dtype=dtype)}
    raise ValueError(kind)


def apply_mlp(params, x, kind: str, **imc):
    if kind in ("swiglu", "geglu"):
        g = dense(params["w_gate"], x, **imc)
        u = dense(params["w_up"], x, **imc)
        g = shard_hint(g, "ffn")
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        return dense(params["w_down"], act * u, **imc)
    u = dense(params["w_up"], x, **imc)
    u = shard_hint(u, "ffn")
    return dense(params["w_down"], jax.nn.gelu(u), **imc)
