"""GQA attention: full-causal and sliding-window, train/prefill/decode.

Memory-bounded by construction (framework targets 500k-token caches):
  * train/prefill run a scan over query chunks; global layers score each chunk
    against the full K/V (peak = one chunk of scores), local layers slice only
    a window+chunk K/V span (O(S*W) total work).
  * decode uses a single-token query against the cache; local layers keep a
    ring buffer of ``window`` entries, so a 500k-context local layer costs
    O(window), not O(S).

Cache entry per attention layer: {"k","v": (B, T_alloc, KV, hd) roped keys,
"key_pos": (B, T_alloc) int32 absolute positions (-1 = empty)} — explicit
positions make ring-buffer semantics exact and testable.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense, init_dense, shard_hint

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   dtype=jnp.bfloat16):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": init_dense(kk, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": init_dense(kv, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": init_dense(ko, n_heads * head_dim, d_model, dtype=dtype),
    }


def _project_qkv(params, x, n_heads, n_kv_heads, head_dim, positions,
                 rope_theta, **imc):
    b, s, _ = x.shape
    q = dense(params["wq"], x, **imc).reshape(b, s, n_heads, head_dim)
    k = dense(params["wk"], x, **imc).reshape(b, s, n_kv_heads, head_dim)
    v = dense(params["wv"], x, **imc).reshape(b, s, n_kv_heads, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    q = shard_hint(q, "heads")
    # K/V replicated across TP once per layer -> the q-chunk loop contracts
    # locally instead of resharding score-sized tensors every chunk (§Perf)
    k = shard_hint(k, "kv_rep")
    v = shard_hint(v, "kv_rep")
    return q, k, v


def _sdpa(q, k, v, mask, *, native_dtype_dots: bool = True):
    """q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd); mask: (B,1,1,Sq,Sk) or broadcastable.

    Grouped formulation keeps the KV axis explicit (no materialized repeat).
    ``native_dtype_dots``: contract in the input dtype with f32 ACCUMULATION
    (flash-attention numerics).  The alternative (cast operands to f32 first)
    doubles the bytes of every sharded-operand collective inside the chunk
    loop (§Perf iteration 2); softmax always runs in f32 either way.
    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    qg = q.reshape(b, sq, kv, rep, hd)
    if not native_dtype_dots:
        qg, k, v = (t.astype(jnp.float32) for t in (qg, k, v))
    scores = jnp.einsum("bqkrd,btkd->bkrqt", qg, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrqt,btkd->bqkrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _chunked_causal(q, k, v, *, window: int = 0, q_chunk: int = 512,
                    chunk_remat: bool = True, native_dtype_dots: bool = True):
    """Causal (optionally windowed) attention via a scan over query chunks.

    ``chunk_remat`` rematerializes each chunk's scores in the backward pass —
    without it the scan backward saves stacked per-chunk score tensors, i.e.
    the full S x T score matrix the chunking exists to avoid (measured ~6 TB
    of HBM traffic on qwen2.5 train_4k; see EXPERIMENTS §Perf iteration 1).
    """
    b, s, h, hd = q.shape
    chunk = q_chunk if s % q_chunk == 0 else s
    nc = s // chunk
    if nc == 1:
        qp = jnp.arange(s)[:, None]
        kp = jnp.arange(s)[None, :]
        mask = kp <= qp
        if window:
            mask &= kp > qp - window
        return _sdpa(q, k, v, mask[None, None, None],
                     native_dtype_dots=native_dtype_dots)

    qs = q.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    if window and window + chunk < s:
        span = window + chunk  # static slice size covering the window

        def body(_, args):
            ci, qc = args
            q_start = ci * chunk
            k_start = jnp.clip(q_start + chunk - span, 0, s - span)
            kc = jax.lax.dynamic_slice_in_dim(k, k_start, span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, k_start, span, axis=1)
            qp = (q_start + jnp.arange(chunk))[:, None]
            kp = (k_start + jnp.arange(span))[None, :]
            mask = (kp <= qp) & (kp > qp - window)
            return None, _sdpa(qc, kc, vc, mask[None, None, None],
                               native_dtype_dots=native_dtype_dots)
    else:
        def body(_, args):
            ci, qc = args
            q_start = ci * chunk
            qp = (q_start + jnp.arange(chunk))[:, None]
            kp = jnp.arange(s)[None, :]
            mask = kp <= qp
            if window:
                mask &= kp > qp - window
            return None, _sdpa(qc, k, v, mask[None, None, None],
                               native_dtype_dots=native_dtype_dots)

    if chunk_remat:
        body = jax.checkpoint(body)
    _, out = jax.lax.scan(body, None, (jnp.arange(nc), qs))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


class AttnCache(NamedTuple):
    k: jnp.ndarray  # (B, T_alloc, KV, hd) roped keys (bf16 or int8)
    v: jnp.ndarray
    key_pos: jnp.ndarray  # (B, T_alloc) int32; -1 = empty slot
    k_scale: jnp.ndarray | None = None  # (B, T_alloc, KV) f16 when int8 cache
    v_scale: jnp.ndarray | None = None


def _kv_quant(x):
    """Per-(B,T,KV) int8 quantization of roped K/V (amax over head_dim)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
            ).astype(dtype)


def attn_forward(params, x, *, n_heads, n_kv_heads, head_dim, rope_theta,
                 window: int = 0, positions=None, q_chunk: int = 512,
                 chunk_remat: bool = True, native_dtype_dots: bool = True,
                 use_flash: bool = False, **imc):
    """Training / no-cache forward. x: (B, S, D) -> (B, S, D)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim,
                           positions, rope_theta, **imc)
    if use_flash:
        from repro.kernels.flash_attn.ops import flash_attention

        out = flash_attention(q, k, v, window=window)
    else:
        out = _chunked_causal(q, k, v, window=window, q_chunk=q_chunk,
                              chunk_remat=chunk_remat,
                              native_dtype_dots=native_dtype_dots)
    return dense(params["wo"], out.reshape(b, s, -1), **imc)


def attn_prefill(params, x, *, n_heads, n_kv_heads, head_dim, rope_theta,
                 window: int = 0, cache_len: int | None = None,
                 q_chunk: int = 512, kv_dtype: str = "bf16", **imc):
    """Prefill: forward over the prompt AND build the decode cache.

    cache_len defaults to S for global layers, window for local layers.
    ``kv_dtype="int8"`` stores quantized K/V + per-(B,T,KV) scales (halves
    decode HBM traffic; see EXPERIMENTS §Perf).
    """
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim,
                           positions, rope_theta, **imc)
    out = _chunked_causal(q, k, v, window=window, q_chunk=q_chunk)
    t_alloc = cache_len if cache_len is not None else (window if window else s)
    if t_alloc <= s:  # keep the last t_alloc entries, ring-aligned so that
        # entry for position p sits at slot p % t_alloc (decode invariant)
        shift = s % t_alloc
        ck = jnp.roll(k[:, s - t_alloc:], shift, axis=1)
        cv = jnp.roll(v[:, s - t_alloc:], shift, axis=1)
        cp = jnp.roll(jnp.broadcast_to(
            jnp.arange(s - t_alloc, s)[None], (b, t_alloc)), shift, axis=1)
    else:  # roomier cache than the prompt: left-fill
        pad = t_alloc - s
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cp = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(s)[None], (b, s)),
             jnp.full((b, pad), -1, jnp.int32)], axis=1)
    if kv_dtype == "int8":
        ck, ks = _kv_quant(ck)
        cv, vs = _kv_quant(cv)
        cache = AttnCache(ck, cv, cp.astype(jnp.int32), ks, vs)
    else:
        cache = AttnCache(ck, cv, cp.astype(jnp.int32))
    y = dense(params["wo"], out.reshape(b, s, -1), **imc)
    return y, cache


def attn_decode(params, x, cache: AttnCache, pos, *, n_heads, n_kv_heads,
                head_dim, rope_theta, window: int = 0, **imc):
    """One-token decode. x: (B, 1, D); pos: scalar int32 OR (B,) int32 —
    per-row positions support continuous batching, where slots admitted at
    different ticks sit at different sequence positions.

    Writes each row's new K/V into slot ``pos % T_alloc`` (ring semantics for
    local layers; for global layers T_alloc == context so the slot is just
    ``pos``).
    """
    b = x.shape[0]
    t_alloc = cache.k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    positions = (pos if pos.ndim else jnp.full((b,), pos))[:, None]  # (B,1)
    q, k_new, v_new = _project_qkv(params, x, n_heads, n_kv_heads, head_dim,
                                   positions, rope_theta, **imc)
    rows = jnp.arange(b)
    slot = jnp.mod(positions[:, 0], t_alloc)  # (B,) per-row ring index
    int8_cache = cache.k_scale is not None
    if int8_cache:
        kq_new, ks_new = _kv_quant(k_new)
        vq_new, vs_new = _kv_quant(v_new)
        kq = cache.k.at[rows, slot].set(kq_new[:, 0])
        vq = cache.v.at[rows, slot].set(vq_new[:, 0])
        ks = cache.k_scale.at[rows, slot].set(ks_new[:, 0])
        vs = cache.v_scale.at[rows, slot].set(vs_new[:, 0])
        k = _kv_dequant(kq, ks, q.dtype)
        v = _kv_dequant(vq, vs, q.dtype)
    else:
        k = cache.k.at[rows, slot].set(k_new[:, 0].astype(cache.k.dtype))
        v = cache.v.at[rows, slot].set(v_new[:, 0].astype(cache.v.dtype))
    key_pos = cache.key_pos.at[rows, slot].set(positions[:, 0])
    valid = (key_pos >= 0) & (key_pos <= positions)  # (B,T)
    if window:
        valid &= key_pos > positions - window
    mask = valid[:, None, None, None, :]  # (B,1,1,1,T)
    out = _sdpa(q, k, v, mask)
    y = dense(params["wo"], out.reshape(b, 1, -1), **imc)
    if int8_cache:
        return y, AttnCache(kq, vq, key_pos, ks, vs)
    return y, AttnCache(k, v, key_pos)
