"""GQA attention: full-causal and sliding-window, train/prefill/decode.

Memory-bounded by construction (framework targets 500k-token caches):
  * train/prefill run a scan over query chunks; global layers score each chunk
    against the full K/V (peak = one chunk of scores), local layers slice only
    a window+chunk K/V span (O(S*W) total work).
  * decode uses a single-token query against the cache; local layers keep a
    ring buffer of ``window`` entries, so a 500k-context local layer costs
    O(window), not O(S).

Cache entry per attention layer: {"k","v": (B, T_alloc, KV, hd) roped keys,
"key_pos": (B, T_alloc) int32 absolute positions (-1 = empty)} — explicit
positions make ring-buffer semantics exact and testable.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense, init_dense, shard_hint

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   dtype=jnp.bfloat16):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": init_dense(kk, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": init_dense(kv, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": init_dense(ko, n_heads * head_dim, d_model, dtype=dtype),
    }


def _project_qkv(params, x, n_heads, n_kv_heads, head_dim, positions,
                 rope_theta, **imc):
    b, s, _ = x.shape
    q = dense(params["wq"], x, **imc).reshape(b, s, n_heads, head_dim)
    k = dense(params["wk"], x, **imc).reshape(b, s, n_kv_heads, head_dim)
    v = dense(params["wv"], x, **imc).reshape(b, s, n_kv_heads, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    q = shard_hint(q, "heads")
    # K/V replicated across TP once per layer -> the q-chunk loop contracts
    # locally instead of resharding score-sized tensors every chunk (§Perf)
    k = shard_hint(k, "kv_rep")
    v = shard_hint(v, "kv_rep")
    return q, k, v


def _sdpa(q, k, v, mask, *, native_dtype_dots: bool = True):
    """q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd); mask: (B,1,1,Sq,Sk) or broadcastable.

    Grouped formulation keeps the KV axis explicit (no materialized repeat).
    ``native_dtype_dots``: contract in the input dtype with f32 ACCUMULATION
    (flash-attention numerics).  The alternative (cast operands to f32 first)
    doubles the bytes of every sharded-operand collective inside the chunk
    loop (§Perf iteration 2); softmax always runs in f32 either way.
    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    qg = q.reshape(b, sq, kv, rep, hd)
    if not native_dtype_dots:
        qg, k, v = (t.astype(jnp.float32) for t in (qg, k, v))
    scores = jnp.einsum("bqkrd,btkd->bkrqt", qg, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrqt,btkd->bqkrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _chunked_causal(q, k, v, *, window: int = 0, q_chunk: int = 512,
                    chunk_remat: bool = True, native_dtype_dots: bool = True):
    """Causal (optionally windowed) attention via a scan over query chunks.

    ``chunk_remat`` rematerializes each chunk's scores in the backward pass —
    without it the scan backward saves stacked per-chunk score tensors, i.e.
    the full S x T score matrix the chunking exists to avoid (measured ~6 TB
    of HBM traffic on qwen2.5 train_4k; see EXPERIMENTS §Perf iteration 1).
    """
    b, s, h, hd = q.shape
    chunk = q_chunk if s % q_chunk == 0 else s
    nc = s // chunk
    if nc == 1:
        qp = jnp.arange(s)[:, None]
        kp = jnp.arange(s)[None, :]
        mask = kp <= qp
        if window:
            mask &= kp > qp - window
        return _sdpa(q, k, v, mask[None, None, None],
                     native_dtype_dots=native_dtype_dots)

    qs = q.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    if window and window + chunk < s:
        span = window + chunk  # static slice size covering the window

        def body(_, args):
            ci, qc = args
            q_start = ci * chunk
            k_start = jnp.clip(q_start + chunk - span, 0, s - span)
            kc = jax.lax.dynamic_slice_in_dim(k, k_start, span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, k_start, span, axis=1)
            qp = (q_start + jnp.arange(chunk))[:, None]
            kp = (k_start + jnp.arange(span))[None, :]
            mask = (kp <= qp) & (kp > qp - window)
            return None, _sdpa(qc, kc, vc, mask[None, None, None],
                               native_dtype_dots=native_dtype_dots)
    else:
        def body(_, args):
            ci, qc = args
            q_start = ci * chunk
            qp = (q_start + jnp.arange(chunk))[:, None]
            kp = jnp.arange(s)[None, :]
            mask = kp <= qp
            if window:
                mask &= kp > qp - window
            return None, _sdpa(qc, k, v, mask[None, None, None],
                               native_dtype_dots=native_dtype_dots)

    if chunk_remat:
        body = jax.checkpoint(body)
    _, out = jax.lax.scan(body, None, (jnp.arange(nc), qs))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


class AttnCache(NamedTuple):
    k: jnp.ndarray  # (B, T_alloc, KV, hd) roped keys (bf16 or int8)
    v: jnp.ndarray
    key_pos: jnp.ndarray  # (B, T_alloc) int32; -1 = empty slot
    k_scale: jnp.ndarray | None = None  # (B, T_alloc, KV) f16 when int8 cache
    v_scale: jnp.ndarray | None = None


class PagedAttnCache(NamedTuple):
    """One attention layer's paged KV pool, shared by all batch slots.

    ``k``/``v``: (num_blocks, block_size, KV, hd) — bf16, or int8 with
    per-(block, offset, KV) f16 scale pools.  Logical position ``p`` of batch
    row ``b`` lives at physical row ``table[b, p // bs] * bs + p % bs``; the
    per-slot block table (built by
    :class:`~repro.models.kv_cache.BlockAllocator`) rides into
    :func:`attn_decode` as a traced argument, so growing/retiring requests
    never retraces.  There is no ``key_pos`` leaf: validity is derived from
    ``pos`` and the table (block ``j`` of a slot always covers positions
    ``[j*bs, (j+1)*bs)``).
    """

    k: jnp.ndarray
    v: jnp.ndarray
    k_scale: jnp.ndarray | None = None
    v_scale: jnp.ndarray | None = None


def _kv_quant(x):
    """Per-(B,T,KV) int8 quantization of roped K/V (amax over head_dim)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
            ).astype(dtype)


def attn_forward(params, x, *, n_heads, n_kv_heads, head_dim, rope_theta,
                 window: int = 0, positions=None, q_chunk: int = 512,
                 chunk_remat: bool = True, native_dtype_dots: bool = True,
                 use_flash: bool = False, **imc):
    """Training / no-cache forward. x: (B, S, D) -> (B, S, D)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim,
                           positions, rope_theta, **imc)
    if use_flash:
        from repro.kernels.flash_attn.ops import flash_attention

        out = flash_attention(q, k, v, window=window)
    else:
        out = _chunked_causal(q, k, v, window=window, q_chunk=q_chunk,
                              chunk_remat=chunk_remat,
                              native_dtype_dots=native_dtype_dots)
    return dense(params["wo"], out.reshape(b, s, -1), **imc)


def attn_prefill(params, x, *, n_heads, n_kv_heads, head_dim, rope_theta,
                 window: int = 0, cache_len: int | None = None,
                 q_chunk: int = 512, kv_dtype: str = "bf16",
                 true_len=None, use_flash: bool = False, **imc):
    """Prefill: forward over the prompt AND build the decode cache.

    cache_len defaults to S for global layers, window for local layers.
    ``kv_dtype="int8"`` stores quantized K/V + per-(B,T,KV) scales (halves
    decode HBM traffic; see EXPERIMENTS §Perf).

    ``true_len`` (traced scalar) marks a right-padded prompt: positions
    ``>= true_len`` get ``key_pos = -1`` so downstream consumers (ring decode
    masking, the paged-cache scatter) treat the padded tail as empty.  The
    forward itself needs no extra masking — causal attention already keeps
    padded keys out of every valid query row — so one bucketed executable
    serves all prompt lengths up to S bit-identically.  That same causal
    argument makes ``use_flash`` (the Pallas flash kernel) safe under
    right-padding: per-bucket ``s_valid`` is the padded length, and padded
    *query* rows produce garbage that the cache scatter (key_pos = -1) and
    the caller's logit slicing at ``true_len - 1`` never consume.
    """
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim,
                           positions, rope_theta, **imc)
    if use_flash:
        from repro.kernels.flash_attn.ops import flash_attention

        out = flash_attention(q, k, v, window=window)
    else:
        out = _chunked_causal(q, k, v, window=window, q_chunk=q_chunk)
    t_alloc = cache_len if cache_len is not None else (window if window else s)
    if t_alloc <= s:  # keep the last t_alloc entries, ring-aligned so that
        # entry for position p sits at slot p % t_alloc (decode invariant)
        shift = s % t_alloc
        ck = jnp.roll(k[:, s - t_alloc:], shift, axis=1)
        cv = jnp.roll(v[:, s - t_alloc:], shift, axis=1)
        cp = jnp.roll(jnp.broadcast_to(
            jnp.arange(s - t_alloc, s)[None], (b, t_alloc)), shift, axis=1)
    else:  # roomier cache than the prompt: left-fill
        pad = t_alloc - s
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cp = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(s)[None], (b, s)),
             jnp.full((b, pad), -1, jnp.int32)], axis=1)
    if true_len is not None:
        cp = jnp.where(cp < jnp.asarray(true_len, jnp.int32), cp, -1)
    if kv_dtype == "int8":
        ck, ks = _kv_quant(ck)
        cv, vs = _kv_quant(cv)
        cache = AttnCache(ck, cv, cp.astype(jnp.int32), ks, vs)
    else:
        cache = AttnCache(ck, cv, cp.astype(jnp.int32))
    y = dense(params["wo"], out.reshape(b, s, -1), **imc)
    return y, cache


def _attn_decode_paged(params, x, cache: PagedAttnCache, pos, block_table, *,
                       n_heads, n_kv_heads, head_dim, rope_theta,
                       window: int = 0, attn_impl: str = "jnp", **imc):
    """One-token decode against the shared paged pools.

    x: (B, 1, D); pos: (B,) int32; block_table: (B, MB) int32, -1 = empty.
    Each row writes its new K/V at flat pool row
    ``table[pos // bs] * bs + pos % bs`` (rows of inactive slots map out of
    bounds and are dropped), then attends over the fixed logical span
    ``MB * bs`` through its table via
    :func:`repro.kernels.paged_attn.ops.paged_attention`.  Gather row ``i``
    IS position ``i`` (tables are dense prefixes), so the validity mask is
    just ``i <= pos`` limited to allocated blocks.

    ``attn_impl="jnp"`` is the dense gather path — bit-identical to the ring
    oracle because the extra masked rows contribute exact zeros.
    ``attn_impl="pallas"`` runs the fused flash-decode kernel: it reads the
    post-scatter pools block-by-block through the table (the gathered span
    never touches HBM), within one output ulp of the jnp path (online
    softmax rounds its rescaling differently from one-shot softmax).
    """
    b = x.shape[0]
    nb, bs = cache.k.shape[0], cache.k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    positions = (pos if pos.ndim else jnp.full((b,), pos))[:, None]  # (B,1)
    q, k_new, v_new = _project_qkv(params, x, n_heads, n_kv_heads, head_dim,
                                   positions, rope_theta, **imc)
    tbl = jnp.where(block_table < 0, nb, block_table)  # (B, MB) OOB sentinel
    p = positions[:, 0]
    widx = tbl[jnp.arange(b), jnp.clip(p, 0, None) // bs] * bs + p % bs  # (B,)

    def put(pool, new):  # pool (NB, bs, *tail); new (B, *tail)
        flat = pool.reshape((nb * bs,) + pool.shape[2:])
        return flat.at[widx].set(new.astype(pool.dtype),
                                 mode="drop").reshape(pool.shape)

    int8_cache = cache.k_scale is not None
    if int8_cache:
        kq_new, ks_new = _kv_quant(k_new)
        vq_new, vs_new = _kv_quant(v_new)
        new_cache = PagedAttnCache(put(cache.k, kq_new[:, 0]),
                                   put(cache.v, vq_new[:, 0]),
                                   put(cache.k_scale, ks_new[:, 0]),
                                   put(cache.v_scale, vs_new[:, 0]))
    else:
        new_cache = PagedAttnCache(put(cache.k, k_new[:, 0]),
                                   put(cache.v, v_new[:, 0]))
    from repro.kernels.paged_attn.ops import paged_attention

    out = paged_attention(q, new_cache.k, new_cache.v, block_table, p,
                          k_scale=new_cache.k_scale,
                          v_scale=new_cache.v_scale, window=window,
                          impl=attn_impl)
    y = dense(params["wo"], out.reshape(b, 1, -1), **imc)
    return y, new_cache


def attn_decode(params, x, cache, pos, *, n_heads, n_kv_heads,
                head_dim, rope_theta, window: int = 0, block_table=None,
                attn_impl: str = "jnp", **imc):
    """One-token decode. x: (B, 1, D); pos: scalar int32 OR (B,) int32 —
    per-row positions support continuous batching, where slots admitted at
    different ticks sit at different sequence positions.

    Ring path (``cache`` an :class:`AttnCache`): writes each row's new K/V
    into slot ``pos % T_alloc`` (ring semantics for local layers; for global
    layers T_alloc == context so the slot is just ``pos``).  Paged path
    (``cache`` a :class:`PagedAttnCache`): routes through the per-slot
    ``block_table`` instead — the ring stays the tested oracle.
    ``attn_impl`` selects the paged engine ("jnp" dense gather oracle /
    "pallas" fused flash-decode kernel); the ring path ignores it.
    """
    if isinstance(cache, PagedAttnCache):
        assert block_table is not None, "paged decode needs a block table"
        return _attn_decode_paged(params, x, cache, pos, block_table,
                                  n_heads=n_heads, n_kv_heads=n_kv_heads,
                                  head_dim=head_dim, rope_theta=rope_theta,
                                  window=window, attn_impl=attn_impl, **imc)
    b = x.shape[0]
    t_alloc = cache.k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    positions = (pos if pos.ndim else jnp.full((b,), pos))[:, None]  # (B,1)
    q, k_new, v_new = _project_qkv(params, x, n_heads, n_kv_heads, head_dim,
                                   positions, rope_theta, **imc)
    rows = jnp.arange(b)
    slot = jnp.mod(positions[:, 0], t_alloc)  # (B,) per-row ring index
    int8_cache = cache.k_scale is not None
    if int8_cache:
        kq_new, ks_new = _kv_quant(k_new)
        vq_new, vs_new = _kv_quant(v_new)
        kq = cache.k.at[rows, slot].set(kq_new[:, 0])
        vq = cache.v.at[rows, slot].set(vq_new[:, 0])
        ks = cache.k_scale.at[rows, slot].set(ks_new[:, 0])
        vs = cache.v_scale.at[rows, slot].set(vs_new[:, 0])
        k = _kv_dequant(kq, ks, q.dtype)
        v = _kv_dequant(vq, vs, q.dtype)
    else:
        k = cache.k.at[rows, slot].set(k_new[:, 0].astype(cache.k.dtype))
        v = cache.v.at[rows, slot].set(v_new[:, 0].astype(cache.v.dtype))
    key_pos = cache.key_pos.at[rows, slot].set(positions[:, 0])
    valid = (key_pos >= 0) & (key_pos <= positions)  # (B,T)
    if window:
        valid &= key_pos > positions - window
    mask = valid[:, None, None, None, :]  # (B,1,1,1,T)
    out = _sdpa(q, k, v, mask)
    y = dense(params["wo"], out.reshape(b, 1, -1), **imc)
    if int8_cache:
        return y, AttnCache(kq, vq, key_pos, ks, vs)
    return y, AttnCache(k, v, key_pos)
