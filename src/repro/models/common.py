"""Shared model utilities: norms, rope, dense layers (optionally IMC-backed),
init helpers, and mesh-axis sharding hints.

Models are pure-functional (params = plain pytrees of jnp arrays).  Sharding
hints are optional: the launcher installs an :class:`AxisCtx` and layers call
:func:`shard_hint`; without a context the hints are no-ops, so the same model
code runs single-device (tests/examples) and multi-pod (dryrun/train).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.fabric import FabricSpec
from repro.core.imc_linear import imc_linear_apply

# ------------------------------------------------------------- sharding hints
_AXIS_CTX = threading.local()


@dataclass(frozen=True)
class AxisCtx:
    dp: Union[str, Sequence[str], None]  # data-parallel mesh axes (batch)
    tp: Optional[str]  # tensor-parallel mesh axis


def set_axis_ctx(ctx: Optional[AxisCtx]):
    _AXIS_CTX.value = ctx


def get_axis_ctx() -> Optional[AxisCtx]:
    return getattr(_AXIS_CTX, "value", None)


class axis_ctx:
    """Context manager: with axis_ctx(AxisCtx(("pod","data"), "model")): ..."""

    def __init__(self, ctx: Optional[AxisCtx]):
        self.ctx = ctx

    def __enter__(self):
        self.prev = get_axis_ctx()
        set_axis_ctx(self.ctx)
        return self.ctx

    def __exit__(self, *exc):
        set_axis_ctx(self.prev)


def shard_hint(x, kind: str):
    """Constrain intermediate sharding; no-op without an AxisCtx.

    kinds: "residual" (B, S, D) -> P(dp, tp, None)   [sequence parallelism]
           "heads"    (B, S, H, d) -> P(dp, None, tp, None)
           "ffn"      (B, S, F) -> P(dp, None, tp)
           "logits"   (B, S, V) -> P(dp, None, tp)
           "expert"   (E, C, D) -> P(tp, dp, None)

    Every axis is divisibility-guarded against the ambient (abstract) mesh, so
    the same model code serves 1-device tests and 512-chip lowering.
    """
    ctx = get_axis_ctx()
    if ctx is None:
        return x
    from repro.launch.compat import ambient_mesh

    mesh = ambient_mesh()
    if mesh is None:
        return x
    dp, tp = ctx.dp, ctx.tp
    spec = {
        "residual": (dp, tp, None),
        "heads": (dp, None, tp, None),
        "ffn": (dp, None, tp),
        "logits": (dp, None, tp),
        "expert": (tp, dp, None),
        "tokens": (dp, None),  # flattened (B*S, D) token tables
        "expert_flat": ((tp,) + (dp if isinstance(dp, tuple) else (dp,)),
                        None),  # (E*C, D) dispatch tables, E-major
        "kv_rep": (dp, None, None, None),  # K/V gathered ONCE per layer:
        # keeps the chunked-attention loop collective-free (Megatron-SP style)
    }[kind]

    def axis_size(ax):
        if ax is None:
            return 1
        if isinstance(ax, (tuple, list)):
            n = 1
            for a in ax:
                n *= mesh.shape[a]
            return n
        return mesh.shape[ax]

    fixed = tuple(ax if dim % axis_size(ax) == 0 else None
                  for dim, ax in zip(x.shape, spec))
    return jax.lax.with_sharding_constraint(x, P(*fixed))


# ---------------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, S, H, d); positions: (B, S) or (S,) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # (B, S, 1, d/2)
    sin = sin[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- dense layers
def init_dense(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.bfloat16, scale: float | None = None):
    s = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


# Ambient PRNG source for noisy fabric specs in model code paths that don't
# thread keys explicitly (eager robustness studies; see fabric_noise_key).
_FABRIC_KEY = threading.local()


class fabric_noise_key:
    """Context manager: provide the PRNG key noisy FabricSpecs draw from.

    ``with fabric_noise_key(key): forward_logits(...)`` — each ``dense`` call
    under a noisy spec folds a fresh stream off the key (trace-order counter),
    so a model forward is fully keyed without threading keys through every
    layer signature.

    Works eagerly AND inside jit: the launch-layer step functions
    (:mod:`repro.launch.steps`) take the per-step key as a regular traced
    argument and enter this context *inside* the jitted function, so the
    folded keys are traced values — re-running the cached executable with a
    new key refreshes the noise.  (Entering the context *outside* a ``jit``
    with a concrete key still bakes the folds in as constants at trace time;
    thread the key through the jitted signature for cached noisy paths.)
    """

    def __init__(self, key):
        self.key = key

    def __enter__(self):
        self.prev = getattr(_FABRIC_KEY, "state", None)
        _FABRIC_KEY.state = {"key": self.key, "n": 0}
        return self

    def __exit__(self, *exc):
        _FABRIC_KEY.state = self.prev


def fold_fabric_key():
    """Fresh fold off the ambient noise key, or None outside the context.

    The stack walker (:func:`repro.models.transformer.stack_forward`) uses
    this to draw one base key per forward and re-seed the context per scanned
    layer group, so groups executed by the same traced scan body still draw
    independent noise.
    """
    st = getattr(_FABRIC_KEY, "state", None)
    if st is None:
        return None
    k = jax.random.fold_in(st["key"], st["n"])
    st["n"] += 1
    return k


def _take_fabric_key(spec):
    k = fold_fabric_key()
    if k is None:
        raise ValueError(
            f"FabricSpec {spec.label} is noisy but no PRNG key is available: "
            "pass key= to dense(), or wrap the forward in "
            "models.common.fabric_noise_key(key)")
    return k


def dense(params, x, *, spec: Optional[FabricSpec] = None, key=None):
    """Dense projection; routes through the IMC fabric when ``spec`` is given.

    This is the paper-technique integration point: every projection in the
    model zoo funnels through here, carrying ONE typed
    :class:`~repro.core.fabric.FabricSpec` instead of loose kwargs.  ``key``
    feeds the spec's noise model (required iff ``spec.noisy``; falls back to
    the ambient :class:`fabric_noise_key` context).
    """
    if spec is not None:
        if spec.noisy and key is None:
            key = _take_fabric_key(spec)
        y = imc_linear_apply(x, params["w"].astype(jnp.float32),
                             params.get("b"), spec=spec, key=key)
        return y.astype(x.dtype)
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))
