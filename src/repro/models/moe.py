"""Mixture-of-Experts FFN: top-k routing via sorted capacity-gather dispatch.

Scalable formulation (no GShard T x E x C one-hot, which is O(tokens x experts
x capacity) memory — ~0.7 TB for qwen3-moe at train_4k):

  1. top-k expert choice per token, flatten to T*k assignments
  2. stable-sort assignments by expert; position-in-expert via counts/cumsum
  3. scatter token ids into an (E, capacity) slot table (overflow dropped)
  4. gather tokens -> (E, C, D), batched expert GEMMs, weighted scatter-add back

Memory is O(T*k + E*C*D) — exactly the active workload.  Experts shard over
the TP axis; the slot table/gathers SPMD-partition as all-to-all-style
exchanges.  Load-balance + router-z losses included.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense, init_dense, shard_hint


def init_moe(key, d_model: int, d_ff: int, n_experts: int, kind: str = "swiglu",
             dtype=jnp.bfloat16):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    p = {
        "router": init_dense(kr, d_model, n_experts, dtype=jnp.float32),
        "w_gate": (jax.random.normal(k1, (n_experts, d_model, d_ff), jnp.float32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (n_experts, d_model, d_ff), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (n_experts, d_ff, d_model), jnp.float32) * s_out).astype(dtype),
    }
    if kind == "gelu":
        del p["w_gate"]
    return p


def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    cap = int(n_tokens * top_k * capacity_factor / n_experts)
    # round to a lane-friendly multiple
    cap = max(((cap + 127) // 128) * 128, top_k)
    return min(cap, n_tokens * top_k)


def apply_moe(params, x, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25, kind: str = "swiglu",
              combine_dtype=jnp.bfloat16, **imc):
    """x: (B, S, D) -> (y, aux); aux = {load_balance_loss, router_z_loss}.

    ``combine_dtype``: accumulation dtype of the scatter-add combine.  bf16
    (default) halves the dominant dispatch-table bytes; f32 is the
    paper-faithful-baseline setting kept for ablation (see EXPERIMENTS §Perf).
    """
    b, s, d = x.shape
    t = b * s
    e, k = n_experts, top_k
    cap = moe_capacity(t, e, k, capacity_factor)
    xf = x.reshape(t, d)

    logits = dense(params["router"], xf.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)  # renormalize top-k

    # ---- sorted dispatch --------------------------------------------------
    flat_e = gate_idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)  # (E,)
    starts = jnp.cumsum(counts) - counts
    slot = jnp.arange(t * k) - starts[sorted_e]  # position within expert block
    keep = slot < cap
    tok = order // k  # source token of each sorted assignment

    # (E*C) slot table of token ids; sentinel T points at a zero row.
    table = jnp.full((e * cap,), t, jnp.int32)
    addr = jnp.where(keep, sorted_e * cap + slot, e * cap)  # overflow -> dropped
    table = table.at[addr].set(tok.astype(jnp.int32), mode="drop")
    gate_table = jnp.zeros((e * cap,), jnp.float32).at[addr].set(
        gate_vals.reshape(-1)[order], mode="drop")

    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    x_pad = shard_hint(x_pad, "tokens")
    expert_in = shard_hint(x_pad[table], "expert_flat").reshape(e, cap, d)
    expert_in = shard_hint(expert_in, "expert")

    # ---- expert GEMMs -----------------------------------------------------
    if kind in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"].astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"].astype(x.dtype))
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        u = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"].astype(x.dtype))
        h = jax.nn.gelu(u)
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))
    expert_out = shard_hint(expert_out, "expert")

    # ---- weighted combine (scatter-add) ------------------------------------
    contrib = (expert_out.reshape(e * cap, d).astype(combine_dtype)
               * gate_table[:, None].astype(combine_dtype))
    contrib = shard_hint(contrib, "expert_flat")
    y = jnp.zeros((t + 1, d), combine_dtype).at[table].add(contrib)
    y = shard_hint(y, "tokens")[:t]

    # ---- aux losses ---------------------------------------------------------
    frac_tokens = jnp.bincount(gate_idx[:, 0], length=e).astype(jnp.float32) / t
    frac_probs = jnp.mean(probs, axis=0)
    lb = e * jnp.sum(frac_tokens * frac_probs)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return y.reshape(b, s, d).astype(x.dtype), {
        "load_balance_loss": lb, "router_z_loss": z}
