"""AdamW with ZeRO-1-friendly state layout and mixed-precision masters.

Design for scale:
  * model params may live in bf16; the optimizer keeps fp32 master copies
    and moments.  State tensors mirror the param pytree, so the launcher can
    assign them ZeRO shardings (extra 'data'-axis sharding on the largest
    divisible dim) independently of the bf16 compute params.
  * global-norm clipping, decoupled weight decay, linear-warmup cosine decay.
  * optional int8 gradient compression with error feedback lives in
    repro.runtime.compression and composes in front of the update.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # () int32
    master: Any  # fp32 master params
    m: Any  # fp32 first moment
    v: Any  # fp32 second moment


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_adamw(params) -> AdamWState:
    # copy=True: a float32 param would otherwise ALIAS its master buffer,
    # which breaks double-donation in jitted train steps.
    f32 = lambda t: jax.tree.map(
        lambda x: jnp.array(x, dtype=jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(jnp.int32(0), f32(params), zeros(params), zeros(params))


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, state: AdamWState, cfg: AdamWConfig,
                 param_dtype=jnp.bfloat16):
    """Returns (new_params (param_dtype), new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)

    def upd(p, m, v):
        return p - lr * (m / bc1 / (jnp.sqrt(v / bc2) + cfg.eps)
                         + cfg.weight_decay * p)

    new_master = jax.tree.map(upd, state.master, new_m, new_v)
    new_params = jax.tree.map(lambda p: p.astype(param_dtype), new_master)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_master, new_m, new_v), metrics
