"""RecurrentGemma-9B (Griffin): RG-LRU + local attention, 2:1 pattern.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000.  Pattern (rglru, rglru, local-attn) x 12 + (rglru, rglru) tail
= 38 blocks; window 2048.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    pattern=("rglru", "rglru", "local"), tail=("rglru", "rglru"),
    window=2048, tie_embeddings=True, mlp="geglu", lru_width=4096, rope_theta=1e4,
    source="arXiv:2402.19427; hf:google/recurrentgemma-9b; unverified",
))
