"""Gemma3-12B: 5:1 local:global attention, head_dim 256, 262k vocab.

[hf:google/gemma-3-12b-pt; unverified] 48L d_model=3840 16H (GQA kv=8)
d_ff=15360 vocab=262144, sliding window 1024, pre+post RMSNorm, GEGLU.
Single rope_theta=1e6 is used for both local and global layers (the released
model uses 1e4 local / 1e6 global; noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024, tie_embeddings=True, mlp="geglu", post_norm=True, rope_theta=1e6,
    source="hf:google/gemma-3-12b-pt; unverified",
))
