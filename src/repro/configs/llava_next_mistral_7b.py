"""LLaVA-NeXT (Mistral-7B backbone): sliding-window attention + vision stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000, window 4096.  The anyres vision tower is a
STUB per assignment: input_specs feed precomputed patch embeddings (1024-d
CLIP features projected into the LM).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000, pattern=("local",), window=4096,
    mlp="swiglu", rope_theta=1e4,
    frontend="vision", frontend_dim=1024,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
))
