"""Qwen3-MoE-30B-A3B: 128 experts top-8, fine-grained (d_ff=768/expert).

[hf:Qwen/Qwen3-30B-A3B] 48L d_model=2048 32H (GQA kv=4) vocab=151936.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, pattern=("moe",), mlp="swiglu",
    n_experts=128, top_k=8, rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B",
))
