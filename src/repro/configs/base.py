"""Config system: ModelConfig dataclass, shape suite, and the arch registry."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.fabric import FabricSpec

# ---------------------------------------------------------------- model config
@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # Layer pattern: period repeated n_groups times (+ optional tail).
    # kinds: "attn" (global), "local" (sliding window), "moe", "rglru", "ssd"
    pattern: Tuple[str, ...] = ("attn",)
    tail: Tuple[str, ...] = ()
    window: int = 0
    mlp: str = "swiglu"  # swiglu | geglu | gelu | none
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    post_norm: bool = False  # extra post-block RMSNorm (gemma3)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_combine_dtype: str = "bf16"  # "f32" = pre-optimization baseline
    kv_dtype: str = "bf16"  # "int8" = quantized decode cache (§Perf)
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    # RG-LRU
    lru_width: int = 0  # 0 -> d_model
    # modality frontend (STUB: precomputed embeddings in, per assignment)
    frontend: str = "none"  # none | audio | vision
    frontend_dim: int = 0
    # IMC integration (the paper's technique as an execution mode).  Two
    # channels, read through the `imc_fabric` property: the typed `fabric`
    # field (authoritative when set), else the deprecated imc_mode/imc_bits
    # pair.  Neither field is rewritten, so dataclasses.replace on either
    # channel behaves predictably; setting both to conflicting values raises.
    fabric: Optional[FabricSpec] = None
    imc_mode: str = "off"  # off | exact | sim (deprecated spelling)
    imc_bits: int = 8
    # numerics / execution
    q_chunk: int = 512
    ssd_chunk: int = 128
    remat: bool = True
    chunk_remat: bool = True  # False = pre-optimization baseline (§Perf iter 1)
    native_dtype_dots: bool = True  # False = f32-cast attention dots (baseline)
    use_flash_kernel: bool = False  # Pallas flash-attn for train AND serving
    # prefill (TTFT); interpret-mode off-TPU
    # Paged-decode attention engine: "jnp" = dense gather through the block
    # table (the oracle), "pallas" = fused flash-decode kernel reading the
    # pools directly (§Perf).  A ModelConfig field so Engine step-cache keys
    # carry it — switching impls can never silently reuse a stale executable.
    attn_impl: str = "jnp"
    # source provenance
    source: str = ""

    def __post_init__(self):
        if self.attn_impl not in ("jnp", "pallas"):
            raise ValueError(
                f"{self.name}: attn_impl must be 'jnp' or 'pallas', "
                f"got {self.attn_impl!r}")
        period = len(self.pattern)
        if (self.n_layers - len(self.tail)) % period != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} incompatible with "
                f"pattern period {period} + tail {len(self.tail)}")
        if (self.fabric is not None and self.imc_mode != "off"
                and (self.imc_mode != self.fabric.mode
                     or self.imc_bits != self.fabric.bits_a)):
            # Both channels set to different things: undecidable intent —
            # raise instead of silently picking one.  (Writes to one channel
            # alone always behave: fabric= governs when set, the legacy pair
            # governs otherwise; see the imc_fabric property.)
            raise ValueError(
                f"{self.name}: ambiguous IMC config — fabric={self.fabric} "
                f"disagrees with legacy imc_mode={self.imc_mode!r}/"
                f"imc_bits={self.imc_bits}; the typed fabric field is "
                "authoritative: clear the legacy channel (imc_mode='off') "
                "or replace fabric= itself (fabric=None turns IMC off)")

    @property
    def imc_fabric(self) -> Optional[FabricSpec]:
        """The active fabric: the typed field, else the legacy pair, else off.

        Model code reads THIS (never the raw fields), so both config
        spellings drive the same spec-typed path.
        """
        if self.fabric is not None:
            return self.fabric
        if self.imc_mode != "off":
            return FabricSpec(bits_a=self.imc_bits, bits_w=self.imc_bits,
                              mode=self.imc_mode)
        return None

    @property
    def n_groups_layers(self) -> int:
        return (self.n_layers - len(self.tail)) // len(self.pattern)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def lru_w(self) -> int:
        return self.lru_width or self.d_model

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.hd
        attn = d * hd * (self.n_heads * 2) + d * hd * (self.n_kv_heads * 2)
        mlp = {"swiglu": 3 * d * f, "geglu": 3 * d * f, "gelu": 2 * d * f,
               "none": 0}[self.mlp]
        moe = self.n_experts * 3 * d * f + d * self.n_experts
        d_in = self.ssm_expand * d
        heads_ssd = d_in // self.ssm_headdim if self.ssm_headdim else 0
        ssd = (d * (2 * d_in + 2 * self.ssm_state + heads_ssd)
               + d_in * d + 3 * heads_ssd + d_in)
        w = self.lru_w
        rglru = 2 * d * w + 2 * w * w + w * d + w * 3
        per_kind = {"attn": attn + mlp, "local": attn + mlp,
                    "moe": attn + moe, "rglru": rglru + mlp, "ssd": ssd}
        total = 0
        layers = list(self.pattern) * self.n_groups_layers + list(self.tail)
        for kind in layers:
            total += per_kind[kind] + 2 * d  # + norms
        total += v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.n_experts == 0:
            return self.n_params()
        full = self.n_params()
        d, f = self.d_model, self.d_ff
        layers = list(self.pattern) * self.n_groups_layers + list(self.tail)
        n_moe = sum(1 for k in layers if k == "moe")
        inactive = n_moe * (self.n_experts - self.top_k) * 3 * d * f
        return full - inactive


# ---------------------------------------------------------------- shape suite
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic decode structure); pure
# full-attention archs skip it (see DESIGN.md §4).
LONG_CONTEXT_ARCHS = {"mamba2-370m", "recurrentgemma-9b", "gemma3-12b"}


# ------------------------------------------------------------------- registry
_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    import importlib

    for mod in ("musicgen_large", "qwen2_72b", "deepseek_coder_33b",
                "qwen2_5_3b", "gemma3_12b", "dbrx_132b", "qwen3_moe_30b_a3b",
                "recurrentgemma_9b", "llava_next_mistral_7b", "mamba2_370m",
                "imc_paper"):
        importlib.import_module(f"repro.configs.{mod}")


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    period = len(cfg.pattern)
    small = dict(
        n_layers=period + len(cfg.tail),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=256,
        window=min(cfg.window, 16) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else cfg.ssm_headdim,
        lru_width=32 if cfg.lru_width or "rglru" in cfg.pattern + cfg.tail else 0,
        frontend_dim=32 if cfg.frontend != "none" else 0,
        q_chunk=16,
        ssd_chunk=8,
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
