"""The paper's own demonstrator scale: a ~110M LM whose every projection runs
through the IMC fabric (exact digital-equivalent path) — used by the
end-to-end training example and the IMC energy-projection benchmarks.
"""
from repro.configs.base import ModelConfig, register
from repro.core.fabric import FabricSpec

CONFIG = register(ModelConfig(
    name="imc-paper-110m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=32000, pattern=("attn",), mlp="gelu",
    fabric=FabricSpec(mode="exact"),
    source="paper demonstrator (8T SRAM IMC, exact path)",
))
