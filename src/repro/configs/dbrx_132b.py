"""DBRX-132B: fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base; unverified] 40L d_model=6144 48H (GQA kv=8)
d_ff=10752/expert vocab=100352, MoE every layer.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352, pattern=("moe",), mlp="swiglu",
    n_experts=16, top_k=4, rope_theta=5e5,
    source="hf:databricks/dbrx-base; unverified",
))
