"""MusicGen-large: decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=2048 32H (MHA) d_ff=8192 vocab=2048.
Audio frontend (EnCodec + codebook interleaving) is a STUB per assignment:
input_specs feed precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=2048, pattern=("attn",), mlp="gelu", rope_theta=1e4,
    frontend="audio", frontend_dim=128,
    source="arXiv:2306.05284; hf:facebook/musicgen-large",
))
