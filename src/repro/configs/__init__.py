from repro.configs.base import (SHAPES, LONG_CONTEXT_ARCHS, ModelConfig,
                                ShapeConfig, get_config, list_configs,
                                reduce_config, register)

ASSIGNED_ARCHS = (
    "musicgen-large", "qwen2-72b", "deepseek-coder-33b", "qwen2.5-3b",
    "gemma3-12b", "dbrx-132b", "qwen3-moe-30b-a3b", "recurrentgemma-9b",
    "llava-next-mistral-7b", "mamba2-370m",
)
