"""Mamba2-370M: attention-free SSD (state-space duality) stack.

[arXiv:2405.21060; unverified] 48L d_model=1024 vocab=50280, ssm_state=128,
headdim=64, expand=2 (d_inner=2048, 32 SSD heads), no FFN.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=50280, pattern=("ssd",), mlp="none",
    ssm_state=128, ssm_headdim=64, ssm_expand=2, tie_embeddings=True,
    source="arXiv:2405.21060; hf:state-spaces/mamba2-370m; unverified",
))
