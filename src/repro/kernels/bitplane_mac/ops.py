"""jit'd public wrapper for the bitplane_mac kernel (planes, padding, thr).

Takes *unsigned multi-bit* operands (offset-binary ints, the same contract as
``core.bitserial.bitserial_matmul_unsigned``), explodes them into bit-planes,
pads every axis to the kernel's block grid, and unpads the result.  Zero
padding is safe end-to-end: a zero bit contributes count 0 and the noise-free
decode maps 0 -> 0, so padded groups add nothing to the accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import constants as C
from repro.core.decoder import thresholds as core_thresholds
from repro.core.quant import to_bitplanes
from repro.kernels.bitplane_mac.bitplane_mac import bitplane_mac_raw
from repro.kernels.compat import resolve_interpret


@functools.partial(jax.jit, static_argnames=("bits_a", "bits_w", "rows",
                                             "bm", "bn", "bk", "interpret"))
def bitplane_mac(u_a, u_w, thr=None, *, bits_a: int = 8, bits_w: int = 8,
                 rows: int = C.ROWS, bm: int = 128, bn: int = 128,
                 bk: int = 256, interpret: bool | None = None):
    """Fused full-pyramid bit-serial matmul for arbitrary shapes.

    u_a: int[..., K] in [0, 2^bits_a); u_w: int[K, N) likewise.  Leading batch
    dims of ``u_a`` flatten into M.  ``thr`` defaults to the physics-model
    comparator references for ``rows`` (re-tunable, paper §IV-C).
    Returns int32[..., N] == u_a @ u_w (noise-free decode is exact).
    """
    interpret = resolve_interpret(interpret)
    if thr is None:
        thr = core_thresholds(rows, mode="physics")
    batch = u_a.shape[:-1]
    m = 1
    for b in batch:
        m *= b
    k = u_a.shape[-1]
    n = u_w.shape[-1]
    a_planes = to_bitplanes(u_a.reshape(m, k), bits_a)  # [PA, M, K]
    w_planes = to_bitplanes(u_w, bits_w)                # [PW, K, N]
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    if pm or pk:
        a_planes = jnp.pad(a_planes, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        w_planes = jnp.pad(w_planes, ((0, 0), (0, pk), (0, pn)))
    out = bitplane_mac_raw(a_planes, w_planes, thr, rows=rows, bm=bm, bn=bn,
                           bk=bk, interpret=interpret)
    return out[:m, :n].reshape(*batch, n)
