"""Public wrappers for the bitplane_mac kernels (planes, padding, geometry).

Both entry points take *unsigned multi-bit* operands (offset-binary ints, the
same contract as ``core.bitserial.bitserial_matmul_unsigned``), explode them
into bit-planes, pad every axis to the kernel's block grid, and unpad the
result.  Zero padding is safe end-to-end: a zero bit contributes count 0 and
the decode maps 0 -> 0 (see the noisy-raw docstring for the noise argument),
so padded groups add nothing to the accumulator.

The wrappers are deliberately PLAIN functions in front of inner jits: tile
geometry defaults to the autotuner's cached winner for the call's shape
bucket (``repro.kernels.autotune``), and that resolution must happen at call
time, outside any jit cache — otherwise a re-tune (or a ``REPRO_TUNE_*`` pin
change) could silently keep executing stale tiles.  The resolved geometry is
then a static argument of the inner jit, so each geometry compiles once.
Explicit ``bm``/``bn``/``bk`` arguments always win over the tuner.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core import constants as C
from repro.core.decoder import thresholds as core_thresholds
from repro.core.quant import to_bitplanes
from repro.kernels import autotune
from repro.kernels.bitplane_mac.bitplane_mac import (bitplane_mac_noisy_raw,
                                                     bitplane_mac_raw)
from repro.kernels.compat import kernel_caps
from repro.telemetry import get_registry


def _resolve_geometry(m: int, k: int, n: int, bits_a: int, bits_w: int,
                      bm, bn, bk, interpret: bool) -> dict:
    geom = autotune.lookup(
        "bitplane_mac",
        {"m": m, "k": k, "n": n, "ba": bits_a, "bw": bits_w},
        interpret=interpret)
    if bm is not None:
        geom["bm"] = bm
    if bn is not None:
        geom["bn"] = bn
    if bk is not None:
        geom["bk"] = bk
    return geom


@functools.partial(jax.jit, static_argnames=("bits_a", "bits_w", "rows",
                                             "bm", "bn", "bk", "interpret"))
def _bitplane_mac_jit(u_a, u_w, thr, *, bits_a, bits_w, rows, bm, bn, bk,
                      interpret):
    batch = u_a.shape[:-1]
    m = 1
    for b in batch:
        m *= b
    k = u_a.shape[-1]
    n = u_w.shape[-1]
    a_planes = to_bitplanes(u_a.reshape(m, k), bits_a)  # [PA, M, K]
    w_planes = to_bitplanes(u_w, bits_w)                # [PW, K, N]
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    if pm or pk:
        a_planes = jnp.pad(a_planes, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        w_planes = jnp.pad(w_planes, ((0, 0), (0, pk), (0, pn)))
    out = bitplane_mac_raw(a_planes, w_planes, thr, rows=rows, bm=bm, bn=bn,
                           bk=bk, interpret=interpret)
    return out[:m, :n].reshape(*batch, n)


def bitplane_mac(u_a, u_w, thr=None, *, bits_a: int = 8, bits_w: int = 8,
                 rows: int = C.ROWS, bm: int | None = None,
                 bn: int | None = None, bk: int | None = None,
                 interpret: bool | None = None):
    """Fused full-pyramid bit-serial matmul for arbitrary shapes.

    u_a: int[..., K] in [0, 2^bits_a); u_w: int[K, N) likewise.  Leading batch
    dims of ``u_a`` flatten into M.  ``thr`` defaults to the physics-model
    comparator references for ``rows`` (re-tunable, paper §IV-C).  Tile
    geometry (bm, bn, bk) defaults to the autotuner's cached winner for this
    shape bucket; pass explicit values to override.
    Returns int32[..., N] == u_a @ u_w (noise-free decode is exact).
    """
    caps = kernel_caps(interpret)
    batch = u_a.shape[:-1]
    m = 1
    for b in batch:
        m *= b
    geom = _resolve_geometry(m, u_a.shape[-1], u_w.shape[-1], bits_a, bits_w,
                             bm, bn, bk, caps.interpret)
    if thr is None:
        thr = core_thresholds(rows, mode="physics")
    return _bitplane_mac_jit(u_a, u_w, thr, bits_a=bits_a, bits_w=bits_w,
                             rows=rows, bm=geom["bm"], bn=geom["bn"],
                             bk=geom["bk"], interpret=caps.interpret)


def _key_words(key):
    """PRNG key -> int32[2] seed words for scalar prefetch.

    Accepts a typed jax PRNG key or a raw uint32 key-data array; folds
    whatever width the impl uses down to two words (threefry2x32 is exactly
    two, rbg is four).
    """
    if jnp.issubdtype(jnp.asarray(key).dtype, jax.dtypes.prng_key):
        data = jax.random.key_data(key)
    else:
        data = jnp.asarray(key)
    data = data.reshape(-1).astype(jnp.uint32)
    if data.shape[0] == 1:
        data = jnp.concatenate([data, data ^ jnp.uint32(0x9E3779B9)])
    elif data.shape[0] > 2:
        folded = data[:2]
        for i in range(2, data.shape[0]):
            folded = folded.at[i % 2].set(folded[i % 2] ^ data[i])
        data = folded
    return jax.lax.bitcast_convert_type(data, jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "bits_a", "bits_w", "rows", "mismatch_sigma", "comparator_offset_sigma",
    "bm", "bn", "bk", "interpret"))
def _bitplane_mac_noisy_jit(u_a, u_w, thr, key, *, bits_a, bits_w, rows,
                            mismatch_sigma, comparator_offset_sigma, bm, bn,
                            bk, interpret):
    batch = u_a.shape[:-1]
    m = 1
    for b in batch:
        m *= b
    k = u_a.shape[-1]
    n = u_w.shape[-1]
    a_planes = to_bitplanes(u_a.reshape(m, k), bits_a)
    w_planes = to_bitplanes(u_w, bits_w)
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    if pm or pk:
        a_planes = jnp.pad(a_planes, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        w_planes = jnp.pad(w_planes, ((0, 0), (0, pk), (0, pn)))
    out = bitplane_mac_noisy_raw(
        a_planes, w_planes, thr, _key_words(key), rows=rows, bm=bm, bn=bn,
        bk=bk, mismatch_sigma=mismatch_sigma,
        comparator_offset_sigma=comparator_offset_sigma,
        valid_groups=-(-k // rows), interpret=interpret)
    return out[:m, :n].reshape(*batch, n)


_WARNED_PRNG_FALLBACK = False


def _prng_fallback(u_a, u_w, key, *, bits_a, bits_w, rows,
                   mismatch_sigma, comparator_offset_sigma):
    """jnp keyed engine fallback when no in-kernel PRNG exists.

    Only reachable on a compiled-TPU jax too old for the Mosaic PRNG
    primitives (interpret mode always has the counter-hash fallback).  Warns
    ONCE per process — an engine switch is a statistics change the user
    should see — and counts every occurrence in telemetry.
    """
    global _WARNED_PRNG_FALLBACK
    if not _WARNED_PRNG_FALLBACK:
        warnings.warn(
            "bitplane_mac_noisy: no in-kernel PRNG on this jax build "
            "(pltpu.prng_seed/prng_random_bits missing); falling back to "
            "the plane-batched jnp noise engine. Results stay statistically "
            "correct but use a different PRNG stream.",
            RuntimeWarning, stacklevel=3)
        _WARNED_PRNG_FALLBACK = True
    get_registry().counter("bitplane_mac.noisy_jnp_fallback").inc()
    from repro.core.bitserial import bitserial_matmul_unsigned

    return bitserial_matmul_unsigned(
        u_a, u_w, bits_a=bits_a, bits_w=bits_w, rows=rows, mode="sim",
        key=key, mismatch_sigma=mismatch_sigma,
        comparator_offset_sigma=comparator_offset_sigma, rbl_mode="physics")


def bitplane_mac_noisy(u_a, u_w, key, thr=None, *, bits_a: int = 8,
                       bits_w: int = 8, rows: int = C.ROWS,
                       mismatch_sigma: float | None = None,
                       comparator_offset_sigma: float | None = None,
                       bm: int | None = None, bn: int | None = None,
                       bk: int | None = None,
                       interpret: bool | None = None):
    """Fused full-pyramid bit-serial matmul with in-kernel NoiseSpec noise.

    Same operand contract as :func:`bitplane_mac` plus ``key`` (a jax PRNG
    key — the ambient ``fabric_noise_key``) and the NoiseSpec sigmas.  The
    whole noisy pyramid runs as ONE ``pallas_call``; same key -> identical
    outputs.  The draw stream differs from the keyed jnp engine's threefry by
    construction, so cross-engine agreement is statistical (moments /
    quantiles), never bitwise — tests pin it that way.
    """
    caps = kernel_caps(interpret)
    if thr is None:
        thr = core_thresholds(rows, mode="physics")
    if not caps.prng:
        return _prng_fallback(
            u_a, u_w, key, bits_a=bits_a, bits_w=bits_w, rows=rows,
            mismatch_sigma=mismatch_sigma,
            comparator_offset_sigma=comparator_offset_sigma)
    batch = u_a.shape[:-1]
    m = 1
    for b in batch:
        m *= b
    geom = _resolve_geometry(m, u_a.shape[-1], u_w.shape[-1], bits_a, bits_w,
                             bm, bn, bk, caps.interpret)
    return _bitplane_mac_noisy_jit(
        u_a, u_w, thr, key, bits_a=bits_a, bits_w=bits_w, rows=rows,
        mismatch_sigma=mismatch_sigma,
        comparator_offset_sigma=comparator_offset_sigma, bm=geom["bm"],
        bn=geom["bn"], bk=geom["bk"], interpret=caps.interpret)
