"""Pallas TPU kernel: the FULL bit-plane pyramid MAC in one pallas_call.

Generalizes ``repro.kernels.rbl_decode`` from one bit-plane pair to all
``bits_a x bits_w`` pairs: per output tile the kernel sweeps plane pairs and
K-blocks, and for every (pair, K-block) it runs the paper's whole evaluation
pipeline — per-8-row-group binary MAC counts, charge-sharing RBL voltage,
comparator thermometer decode, and the ``2^(p+q)``-weighted digital
shift-accumulate — without leaving VMEM:

  out[m, n] = sum_{p,q} 2^{p+q} sum_g decode( V( sum_r a[p, m, g*rows+r]
                                                   * w[q, g*rows+r, n] ) )

The decode is algebraically the identity for noise-free counts, so the result
is bit-identical to the plane-batched jnp engine AND the seed per-plane loop
(``core/bitserial.py``); the point is that the 64-round einsum+decode pyramid
becomes ONE kernel launch with a single int32 accumulator per tile.

Implementation notes (TPU adaptation):
  * grid (M/bm, N/bn, PP, K/bk) with the plane-pair axis PP = bits_a * bits_w
    third and K innermost; both are "arbitrary" (they carry the accumulator),
    M/N tiles are parallel.
  * the index maps recover (p, q) from the flat pair index by div/mod, so the
    activation planes tensor [PA, M, K] and the weight planes tensor
    [PW, K, N] are streamed block-by-block — VMEM never holds more than one
    (bm, bk) + (bk, bn) plane slice.
  * group MACs are a G-batched (bm, rows) x (rows, bn) dot_general as in
    rbl_decode; V(k) is the fitted two-regime physics on the VPU; the
    comparator bank is ``rows`` broadcast compares.
  * the plane weight 2^(p+q) is computed from ``pl.program_id`` on the fly
    (shift of an int32 one), and accumulation is int32 — float32 would lose
    bit-exactness beyond 2^24 for deep-K 8-bit operands.
  * thresholds arrive as a (1, rows) block so corner-re-tuned references
    (paper §IV-C) stay a data, not code, change.

The NOISY sibling (:func:`bitplane_mac_noisy_raw`) keeps the identical grid
and accumulator but runs the :class:`~repro.core.fabric.NoiseSpec`
Monte-Carlo INSIDE the kernel: per grid step it builds a PRNG stream seeded
from (fabric key words, flattened grid-step index) — the Mosaic hardware PRNG
when compiled, the counter-hash fallback in interpret mode
(``kernels.common.make_normal_sampler``) — then applies Gaussian device
mismatch to the effective counts ahead of the RBL voltage map and comparator
offset to the decode references, so all 64 plane pairs x K-groups x decode x
accumulate stay ONE ``pallas_call`` for noisy specs too.  The key words ride
in via scalar prefetch (``pltpu.PrefetchScalarGridSpec``).  Noise draws are
necessarily a different bit stream than the keyed jnp engine's threefry, so
parity with that oracle is statistical (moments/quantiles), never bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import constants as C
from repro.kernels.common import (decode_counts, decode_counts_noisy,
                                  make_normal_sampler)
from repro.kernels.compat import compiler_params


def _make_kernel(rows: int, bk: int, bits_w: int):
    groups = bk // rows

    def kernel(a_ref, b_ref, thr_ref, o_ref, acc_ref):
        pp = pl.program_id(2)
        kk = pl.program_id(3)

        @pl.when((pp == 0) & (kk == 0))
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        bm = a_ref.shape[1]
        bn = b_ref.shape[2]
        a = a_ref[0].astype(jnp.float32).reshape(bm, groups, rows)
        b = b_ref[0].astype(jnp.float32).reshape(groups, rows, bn)
        # counts[g, m, n] = sum_r a[m, g, r] * b[g, r, n]
        counts = jax.lax.dot_general(
            a, b, (((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32)
        dec = decode_counts(counts, thr_ref[...], rows)
        # digital shift-accumulate: weight = 2^(p+q), pair index pp = p*PW + q
        shift = pp // bits_w + pp % bits_w
        weight = jax.lax.shift_left(jnp.int32(1), shift)
        acc_ref[...] += weight * jnp.sum(dec, axis=0).astype(jnp.int32)

        @pl.when((pp == pl.num_programs(2) - 1)
                 & (kk == pl.num_programs(3) - 1))
        def _flush():
            o_ref[...] = acc_ref[...]

    return kernel


@functools.partial(jax.jit, static_argnames=("rows", "bm", "bn", "bk",
                                             "interpret"))
def bitplane_mac_raw(a_planes, w_planes, thresholds, *, rows: int = C.ROWS,
                     bm: int = 128, bn: int = 128, bk: int = 256,
                     interpret: bool = False):
    """Fused full-pyramid decode MAC.

    a_planes: int8[PA, M, K] in {0,1} (activation bit-planes, LSB first);
    w_planes: int8[PW, K, N] in {0,1}; thresholds: float32[rows] descending.
    M, N, K must be divisible by (bm, bn, bk) and bk by rows (ops.py pads).
    Returns int32[M, N] = sum_{p,q} 2^(p+q) * sum_g decoded_count[p, q, g].
    """
    pa, m, k = a_planes.shape
    pw, k2, n = w_planes.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bk % rows == 0
    grid = (m // bm, n // bn, pa * pw, k // bk)
    return pl.pallas_call(
        _make_kernel(rows, bk, pw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda i, j, pp, kk: (pp // pw, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda i, j, pp, kk: (pp % pw, kk, j)),
            pl.BlockSpec((1, rows), lambda i, j, pp, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, pp, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(a_planes.astype(jnp.int8), w_planes.astype(jnp.int8),
      jnp.asarray(thresholds, jnp.float32).reshape(1, rows))


def _make_noisy_kernel(rows: int, bk: int, bits_w: int, mismatch_sigma,
                       comparator_sigma, hw_prng: bool, valid_groups: int):
    groups = bk // rows

    def kernel(seed_ref, a_ref, b_ref, thr_ref, o_ref, acc_ref):
        i = pl.program_id(0)
        j = pl.program_id(1)
        pp = pl.program_id(2)
        kk = pl.program_id(3)

        @pl.when((pp == 0) & (kk == 0))
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # One independent stream per (M-tile, N-tile, plane-pair, K-group):
        # the flattened grid-step index folds into the fabric key words, so
        # no two grid positions (and no two keys) share noise.
        step = ((i * pl.num_programs(1) + j) * pl.num_programs(2) + pp) \
            * pl.num_programs(3) + kk
        normal = make_normal_sampler(
            (seed_ref[0], seed_ref[1], step), hw_prng=hw_prng)

        bm = a_ref.shape[1]
        bn = b_ref.shape[2]
        a = a_ref[0].astype(jnp.float32).reshape(bm, groups, rows)
        b = b_ref[0].astype(jnp.float32).reshape(groups, rows, bn)
        counts = jax.lax.dot_general(
            a, b, (((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32)
        dec = decode_counts_noisy(
            counts, thr_ref[...], rows, normal,
            mismatch_sigma=mismatch_sigma,
            comparator_offset_sigma=comparator_sigma)
        # Padded K-groups (beyond the operand's real K) must not decode:
        # unlike the noise-free kernel — where decode(0) == 0 makes padding
        # free — comparator offset can flip a zero-count group's decode, and
        # the jnp oracle has no such groups at all.  Mask them out.
        g0 = kk * groups
        gidx = g0 + jax.lax.broadcasted_iota(jnp.int32, (groups, 1, 1), 0)
        dec = jnp.where(gidx < valid_groups, dec, 0.0)
        shift = pp // bits_w + pp % bits_w
        weight = jax.lax.shift_left(jnp.int32(1), shift)
        acc_ref[...] += weight * jnp.sum(dec, axis=0).astype(jnp.int32)

        @pl.when((pp == pl.num_programs(2) - 1)
                 & (kk == pl.num_programs(3) - 1))
        def _flush():
            o_ref[...] = acc_ref[...]

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "rows", "bm", "bn", "bk", "mismatch_sigma", "comparator_offset_sigma",
    "valid_groups", "interpret"))
def bitplane_mac_noisy_raw(a_planes, w_planes, thresholds, seed, *,
                           rows: int = C.ROWS, bm: int = 128, bn: int = 128,
                           bk: int = 256, mismatch_sigma=None,
                           comparator_offset_sigma=None,
                           valid_groups: int | None = None,
                           interpret: bool = False):
    """Fused full-pyramid decode MAC with in-kernel NoiseSpec Monte-Carlo.

    Same operand contract as :func:`bitplane_mac_raw`, plus ``seed`` —
    int32[2] PRNG key words (scalar-prefetched) — and the static noise
    sigmas.  ``valid_groups`` is the number of REAL row-groups (pre-padding,
    ``ceil(K_orig / rows)``; defaults to all): groups past it are K-padding
    and their decodes are masked, because comparator offset can flip a
    zero-count group's decode — mismatch alone is padding-safe (stddev
    ``sigma * sqrt(0) = 0``) but the offset term is not, and the jnp oracle
    has no padded groups to draw such flips from.  Returns int32[M, N].
    """
    pa, m, k = a_planes.shape
    pw, k2, n = w_planes.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bk % rows == 0
    if valid_groups is None:
        valid_groups = k // rows
    grid = (m // bm, n // bn, pa * pw, k // bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk),
                         lambda i, j, pp, kk, s: (pp // pw, i, kk)),
            pl.BlockSpec((1, bk, bn),
                         lambda i, j, pp, kk, s: (pp % pw, kk, j)),
            pl.BlockSpec((1, rows), lambda i, j, pp, kk, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, pp, kk, s: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
    )
    return pl.pallas_call(
        _make_noisy_kernel(rows, bk, pw, mismatch_sigma,
                           comparator_offset_sigma, hw_prng=not interpret,
                           valid_groups=valid_groups),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(seed, a_planes.astype(jnp.int8), w_planes.astype(jnp.int8),
      jnp.asarray(thresholds, jnp.float32).reshape(1, rows))
