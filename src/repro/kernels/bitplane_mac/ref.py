"""Pure-jnp oracles for the bitplane_mac kernel (built on repro.core).

Two references, deliberately different engines:

  * :func:`bitplane_mac_ref`        — the SEED per-plane-pair loop
    (``bitserial_matmul_looped``), 64 einsum+decode rounds.
  * :func:`bitplane_mac_batched_ref`— the plane-batched jnp engine
    (``bitserial_matmul_unsigned``), one contraction + one decode.

Both run the analog path with the two-regime physics voltage model (what the
kernel evaluates in-register); noise-free they are bit-identical to each
other and to the kernel.
"""
from __future__ import annotations

from repro.core import constants as C
from repro.core.bitserial import (bitserial_matmul_looped,
                                  bitserial_matmul_unsigned)


def bitplane_mac_ref(u_a, u_w, *, bits_a: int = 8, bits_w: int = 8,
                     rows: int = C.ROWS):
    """Seed-loop oracle: per-plane-pair einsum + physics-mode analog decode."""
    return bitserial_matmul_looped(u_a, u_w, bits_a=bits_a, bits_w=bits_w,
                                   rows=rows, mode="sim", rbl_mode="physics")


def bitplane_mac_batched_ref(u_a, u_w, *, bits_a: int = 8, bits_w: int = 8,
                             rows: int = C.ROWS):
    """Plane-batched oracle: one batched contraction + vectorized decode."""
    return bitserial_matmul_unsigned(u_a, u_w, bits_a=bits_a, bits_w=bits_w,
                                     rows=rows, mode="sim", rbl_mode="physics")
