"""jnp reference for paged decode: the dense gather path, kept as the oracle.

This is (deliberately) the exact computation ``_attn_decode_paged`` ran before
the Pallas kernel existed — gather the full logical span through the block
table into a dense ``(B, T_ctx, KV, hd)`` tensor, dequantize int8 pools, and
run the grouped `_sdpa`.  It reuses :func:`repro.models.attention._sdpa` and
``_kv_dequant`` directly rather than re-implementing them, so ``impl="jnp"``
through the serving stack stays bit-identical to the pre-kernel path by
construction, and kernel parity tests compare against serving-truth numerics
rather than a second hand-rolled softmax.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import _kv_dequant, _sdpa


def paged_decode_ref(q, k_pool, v_pool, block_table, pos, *, k_scale=None,
                     v_scale=None, window: int = 0):
    """Dense-gather paged decode attention (post-scatter pools).

    q: (B, 1, H, hd) roped queries; k_pool/v_pool: (NB, bs, KV, hd) with the
    current token's K/V already written; block_table: (B, MB) int32 dense
    prefixes, ``-1`` = unallocated; pos: (B,) int32.  Returns (B, 1, H, hd).
    """
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    mb = block_table.shape[1]
    t_ctx = mb * bs
    positions = jnp.asarray(pos, jnp.int32)[:, None]  # (B, 1)
    tbl = jnp.where(block_table < 0, nb, block_table)  # OOB sentinel
    ctx = jnp.arange(t_ctx)
    gidx = tbl[:, ctx // bs] * bs + ctx % bs  # (B, T_ctx), OOB >= nb*bs
    valid = (ctx[None, :] <= positions) & (gidx < nb * bs)
    if window:
        valid &= ctx[None, :] > positions - window
    safe = jnp.minimum(gidx, nb * bs - 1)
    kf = k_pool.reshape((nb * bs,) + k_pool.shape[2:])
    vf = v_pool.reshape((nb * bs,) + v_pool.shape[2:])
    if k_scale is not None:
        ks = k_scale.reshape(nb * bs, -1)
        vs = v_scale.reshape(nb * bs, -1)
        k = _kv_dequant(kf[safe], ks[safe], q.dtype)
        v = _kv_dequant(vf[safe], vs[safe], q.dtype)
    else:
        k, v = kf[safe], vf[safe]  # (B, T_ctx, KV, hd)
    mask = valid[:, None, None, None, :]  # (B,1,1,1,T_ctx)
    return _sdpa(q, k, v, mask)
