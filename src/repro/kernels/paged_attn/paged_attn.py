"""Pallas TPU kernel: paged flash-decode attention over per-slot block tables.

The serving hot loop's §Perf finding this addresses: the jnp paged-decode path
gathers every slot's **full logical span** (``MB * block_size`` rows) out of
the shared pools into a dense ``(B, T_ctx, KV, hd)`` HBM tensor — upcast to
f32 again for int8 pools — before a dense SDPA, so per-token HBM traffic
scales with the *allocated* span regardless of how many blocks are live.
Here attention reads the pools **directly** through the block table: the
gathered K/V never exists in HBM, int8 blocks dequantize in-register, and
sentinel (unallocated) table entries are skipped outright.

Grid: ``(B, KV, MB)`` — slot x kv-head x table-block, the block axis
innermost ("arbitrary", carries the online-softmax state).  The block table
and per-slot positions ride in via **scalar prefetch**
(:class:`pltpu.PrefetchScalarGridSpec`), so each step's BlockSpec index map
resolves ``table[b, j]`` *before* the body runs and DMAs exactly one
``(block_size, hd)`` K and V panel from the pool into VMEM.

Per ``(b, h)`` the scratch carries flash-decode state across ``j`` blocks
(the m/l/acc pattern of ``kernels/flash_attn``):

    s      = q_g k_j^T * scale        (rep x bs, MXU)
    m'     = max(m, rowmax(s))        (masked: ctx <= pos, sliding window)
    alpha  = exp(m - m')
    p      = where(valid, exp(s - m'), 0)
    l      = alpha*l + rowsum(p)
    acc    = alpha*acc + p v_j
    out    = acc / l                  (flushed at the last block)

GQA runs **grouped**: q arrives as ``(B, KV, rep, hd)`` (head ``h`` =
``kvh * rep + r``, the `_sdpa` layout), so K/V are never repeated — each
kv-head's ``rep`` query rows share one pool panel.  Blocks whose table entry
is ``-1`` (never allocated) or entirely outside the ``ctx <= pos`` /
sliding-window span are skipped with :func:`pl.when`; their DMA index clamps
to block 0 and the loaded panel is ignored.

Fully-masked slots (inactive: empty table, ``pos == 0``) flush ``acc/l = 0``
— their logits are never consumed by the server.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -1e30


def _make_kernel(bs: int, rep: int, scale: float, window: int, int8: bool):
    def kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, *rest):
        if int8:
            ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
        else:
            o_ref, m_ref, l_ref, acc_ref = rest
        b = pl.program_id(0)
        j = pl.program_id(2)

        @pl.when(j == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        pos = pos_ref[b]
        entry = tbl_ref[b, j]
        base = j * bs
        # A block contributes iff it is allocated (no -1 sentinel) and its
        # span [base, base+bs) intersects the valid context (<= pos, and
        # inside the sliding window when one is set).
        live = (entry >= 0) & (base <= pos)
        if window:
            live &= base + bs > pos - window

        @pl.when(live)
        def _block():
            q = q_ref[0, 0].astype(jnp.float32)       # (rep, hd)
            k = k_ref[0, :, 0].astype(jnp.float32)    # (bs, hd)
            v = v_ref[0, :, 0].astype(jnp.float32)
            if int8:  # in-register dequant against the scale pools
                k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
                v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
            ctx = base + jax.lax.broadcasted_iota(jnp.int32, (rep, bs), 1)
            valid = ctx <= pos
            if window:
                valid &= ctx > pos - window
            s = jnp.where(valid, s, NEG_INF)
            m_prev = m_ref[...]  # (rep, 1)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
            l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
            acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_ref[...] = m_new

        @pl.when(j == pl.num_programs(2) - 1)
        def _flush():
            o_ref[0, 0] = (acc_ref[...]
                           / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("scale", "window", "interpret"))
def paged_flash_decode_raw(q, k_pool, v_pool, k_scale, v_scale, block_table,
                           pos, *, scale: float, window: int = 0,
                           interpret: bool = False):
    """One-token flash decode against shared paged pools.

    q: (B, KV, rep, hd); k_pool/v_pool: (NB, bs, KV, hd) bf16/f32 or int8
    (with k_scale/v_scale (NB, bs, KV) pools, else pass ``None``);
    block_table: (B, MB) int32, ``-1`` = unallocated; pos: (B,) int32 —
    position of the token being decoded (its K/V already written to the
    pool).  Returns (B, KV, rep, hd) in q.dtype.
    """
    b, kv, rep, hd = q.shape
    bs = k_pool.shape[1]
    mb = block_table.shape[1]
    int8 = k_scale is not None
    grid = (b, kv, mb)

    def blk(tbl_ref, pos_ref, bi, ji):
        # Unallocated entries clamp to block 0: the DMA still lands (the
        # pipeline always fetches) but pl.when skips the compute.
        return jnp.maximum(tbl_ref[bi, ji], 0)

    q_spec = pl.BlockSpec((1, 1, rep, hd), lambda b_, h, j, t, p: (b_, h, 0, 0))
    kv_spec = pl.BlockSpec((1, bs, 1, hd),
                           lambda b_, h, j, t, p: (blk(t, p, b_, j), 0, h, 0))
    in_specs = [q_spec, kv_spec, kv_spec]
    inputs = [q, k_pool, v_pool]
    if int8:
        sc_spec = pl.BlockSpec((1, bs, 1),
                               lambda b_, h, j, t, p: (blk(t, p, b_, j), 0, h))
        in_specs += [sc_spec, sc_spec]
        inputs += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rep, hd),
                               lambda b_, h, j, t, p: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _make_kernel(bs, rep, scale, window, int8),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, rep, hd), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, pos, *inputs)
