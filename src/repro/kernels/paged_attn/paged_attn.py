"""Pallas TPU kernel: paged flash-decode attention over per-slot block tables.

The serving hot loop's §Perf finding this addresses: the jnp paged-decode path
gathers every slot's **full logical span** (``MB * block_size`` rows) out of
the shared pools into a dense ``(B, T_ctx, KV, hd)`` HBM tensor — upcast to
f32 again for int8 pools — before a dense SDPA, so per-token HBM traffic
scales with the *allocated* span regardless of how many blocks are live.
Here attention reads the pools **directly** through the block table: the
gathered K/V never exists in HBM, int8 blocks dequantize in-register, and
sentinel (unallocated) table entries are skipped outright.

Grid: ``(B, KV, ceil(MB / bps))`` — slot x kv-head x table-block-group, the
block axis innermost ("arbitrary", carries the online-softmax state).  The
block table and per-slot positions ride in via **scalar prefetch**
(:class:`pltpu.PrefetchScalarGridSpec`), so each step's BlockSpec index maps
resolve ``table[b, j*bps+t]`` *before* the body runs and DMA ``bps``
``(block_size, hd)`` K and V panels from the pool into VMEM —
``bps = blocks_per_step`` (autotuned, default 1) panel fetches in flight per
step, statically unrolled in the body.

Per ``(b, h)`` the scratch carries flash-decode state across ``j`` blocks
(the m/l/acc pattern of ``kernels/flash_attn``):

    s      = q_g k_j^T * scale        (rep x bs, MXU)
    m'     = max(m, rowmax(s))        (masked: ctx <= pos, sliding window)
    alpha  = exp(m - m')
    p      = where(valid, exp(s - m'), 0)
    l      = alpha*l + rowsum(p)
    acc    = alpha*acc + p v_j
    out    = acc / l                  (flushed at the last block)

GQA runs **grouped**: q arrives as ``(B, KV, rep, hd)`` (head ``h`` =
``kvh * rep + r``, the `_sdpa` layout), so K/V are never repeated — each
kv-head's ``rep`` query rows share one pool panel.  Blocks whose table entry
is ``-1`` (never allocated) or entirely outside the ``ctx <= pos`` /
sliding-window span are skipped with :func:`pl.when`; their DMA index clamps
to block 0 and the loaded panel is ignored.

Fully-masked slots (inactive: empty table, ``pos == 0``) flush ``acc/l = 0``
— their logits are never consumed by the server.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -1e30


def _make_kernel(bs: int, rep: int, scale: float, window: int, int8: bool,
                 bps: int, mb: int):
    def kernel(tbl_ref, pos_ref, q_ref, *rest):
        k_refs = rest[0:bps]
        v_refs = rest[bps:2 * bps]
        idx = 2 * bps
        if int8:
            ks_refs = rest[idx:idx + bps]
            vs_refs = rest[idx + bps:idx + 2 * bps]
            idx += 2 * bps
        o_ref, m_ref, l_ref, acc_ref = rest[idx:idx + 4]
        b = pl.program_id(0)
        j = pl.program_id(2)

        @pl.when(j == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        pos = pos_ref[b]
        # Static unroll over the bps table blocks this grid step owns: their
        # panel DMAs were all issued by the pipeline (that is the point —
        # multiple pool fetches in flight per step), the online-softmax
        # update runs sequentially over the live ones.
        for t in range(bps):
            jj = j * bps + t
            entry = tbl_ref[b, jnp.minimum(jj, mb - 1)]
            base = jj * bs
            # A block contributes iff it exists (tail guard for mb % bps),
            # is allocated (no -1 sentinel), and its span [base, base+bs)
            # intersects the valid context (<= pos, and inside the sliding
            # window when one is set).
            live = (jj < mb) & (entry >= 0) & (base <= pos)
            if window:
                live &= base + bs > pos - window

            @pl.when(live)
            def _block(t=t, base=base):
                q = q_ref[0, 0].astype(jnp.float32)            # (rep, hd)
                k = k_refs[t][0, :, 0].astype(jnp.float32)     # (bs, hd)
                v = v_refs[t][0, :, 0].astype(jnp.float32)
                if int8:  # in-register dequant against the scale pools
                    k = k * ks_refs[t][0, :, 0].astype(jnp.float32)[:, None]
                    v = v * vs_refs[t][0, :, 0].astype(jnp.float32)[:, None]
                s = jax.lax.dot_general(
                    q, k, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32) * scale
                ctx = base + jax.lax.broadcasted_iota(jnp.int32, (rep, bs), 1)
                valid = ctx <= pos
                if window:
                    valid &= ctx > pos - window
                s = jnp.where(valid, s, NEG_INF)
                m_prev = m_ref[...]  # (rep, 1)
                m_new = jnp.maximum(m_prev,
                                    jnp.max(s, axis=1, keepdims=True))
                alpha = jnp.exp(m_prev - m_new)
                p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
                l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1,
                                                          keepdims=True)
                acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
                    p, v, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                m_ref[...] = m_new

        @pl.when(j == pl.num_programs(2) - 1)
        def _flush():
            o_ref[0, 0] = (acc_ref[...]
                           / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("scale", "window",
                                             "blocks_per_step", "interpret"))
def paged_flash_decode_raw(q, k_pool, v_pool, k_scale, v_scale, block_table,
                           pos, *, scale: float, window: int = 0,
                           blocks_per_step: int = 1,
                           interpret: bool = False):
    """One-token flash decode against shared paged pools.

    q: (B, KV, rep, hd); k_pool/v_pool: (NB, bs, KV, hd) bf16/f32 or int8
    (with k_scale/v_scale (NB, bs, KV) pools, else pass ``None``);
    block_table: (B, MB) int32, ``-1`` = unallocated; pos: (B,) int32 —
    position of the token being decoded (its K/V already written to the
    pool).  Returns (B, KV, rep, hd) in q.dtype.

    ``blocks_per_step`` (autotuned; see :mod:`repro.kernels.autotune`) packs
    that many consecutive table blocks into one grid step: each gets its own
    input panel with its own index map, so the Pallas pipeline keeps
    ``blocks_per_step`` pool-panel DMAs in flight (double-buffered at 2) per
    step instead of strictly one.  Results are bit-identical across
    ``blocks_per_step`` values — the online-softmax update order over blocks
    is unchanged.
    """
    b, kv, rep, hd = q.shape
    bs = k_pool.shape[1]
    mb = block_table.shape[1]
    int8 = k_scale is not None
    bps = max(1, min(blocks_per_step, mb))
    grid = (b, kv, pl.cdiv(mb, bps))

    def blk(tbl_ref, bi, ji):
        # Unallocated entries clamp to block 0: the DMA still lands (the
        # pipeline always fetches) but pl.when skips the compute.  The ji
        # clamp guards the tail step when mb % bps != 0.
        return jnp.maximum(tbl_ref[bi, jnp.minimum(ji, mb - 1)], 0)

    def kv_map(t):
        return lambda b_, h, j, tbl, p: (blk(tbl, b_, j * bps + t), 0, h, 0)

    def sc_map(t):
        return lambda b_, h, j, tbl, p: (blk(tbl, b_, j * bps + t), 0, h)

    q_spec = pl.BlockSpec((1, 1, rep, hd),
                          lambda b_, h, j, t, p: (b_, h, 0, 0))
    kv_specs = [pl.BlockSpec((1, bs, 1, hd), kv_map(t)) for t in range(bps)]
    in_specs = [q_spec] + kv_specs + kv_specs
    inputs = [q] + [k_pool] * bps + [v_pool] * bps
    if int8:
        sc_specs = [pl.BlockSpec((1, bs, 1), sc_map(t)) for t in range(bps)]
        in_specs += sc_specs + sc_specs
        inputs += [k_scale] * bps + [v_scale] * bps
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rep, hd),
                               lambda b_, h, j, t, p: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _make_kernel(bs, rep, scale, window, int8, bps, mb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, rep, hd), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, pos, *inputs)
