"""Public paged-decode attention op: impl dispatch + GQA grouping.

``paged_attention`` is what the model layer calls.  ``impl="jnp"`` runs the
dense gather oracle (:mod:`.ref` — bit-identical to the pre-kernel serving
path); ``impl="pallas"`` runs the fused flash-decode kernel
(:mod:`.paged_attn`), which reads the pools directly through the block table.
Both take the serving layout — q ``(B, 1, H, hd)``, pools
``(NB, bs, KV, hd)`` — and return ``(B, 1, H, hd)``; the kernel path regroups
heads to the `_sdpa` convention ``(B, KV, rep, hd)`` (head ``h`` =
``kvh * rep + r``) so GQA never materializes a K/V repeat.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.compat import kernel_caps
from repro.kernels.paged_attn.paged_attn import paged_flash_decode_raw
from repro.kernels.paged_attn.ref import paged_decode_ref

ATTN_IMPLS = ("jnp", "pallas")


def paged_attention(q, k_pool, v_pool, block_table, pos, *, k_scale=None,
                    v_scale=None, window: int = 0, impl: str = "jnp",
                    blocks_per_step: int | None = None,
                    interpret: bool | None = None):
    """Paged decode attention against shared pools (post-scatter).

    q: (B, 1, H, hd); k_pool/v_pool: (NB, bs, KV, hd) bf16/f32 or int8 with
    (NB, bs, KV) scale pools; block_table: (B, MB) int32 dense prefixes with
    ``-1`` sentinels; pos: (B,) int32 current positions.  ``interpret=None``
    defers to :func:`repro.kernels.compat.default_interpret` (Pallas
    interpreter off-TPU).  ``blocks_per_step=None`` takes the autotuner's
    cached winner for this shape bucket (pool panels DMA'd per grid step;
    bit-identical across values).  Returns (B, 1, H, hd) in q.dtype.
    """
    if impl not in ATTN_IMPLS:
        raise ValueError(f"impl must be one of {ATTN_IMPLS}, got {impl!r}")
    if impl == "jnp":
        return paged_decode_ref(q, k_pool, v_pool, block_table, pos,
                                k_scale=k_scale, v_scale=v_scale,
                                window=window)
    b, sq, h, hd = q.shape
    assert sq == 1, "paged flash decode is single-token"
    kv = k_pool.shape[2]
    caps = kernel_caps(interpret)
    if blocks_per_step is None:
        blocks_per_step = autotune.lookup(
            "paged_attn",
            {"b": b, "kv": kv, "rep": h // kv, "hd": hd,
             "bs": k_pool.shape[1], "mb": block_table.shape[1]},
            dtype="int8" if k_scale is not None else str(k_pool.dtype),
            interpret=caps.interpret)["bps"]
    qg = q.reshape(b, kv, h // kv, hd)  # grouped heads, sq axis folded away
    out = paged_flash_decode_raw(
        qg, k_pool, v_pool, k_scale, v_scale,
        block_table.astype(jnp.int32), jnp.asarray(pos, jnp.int32),
        scale=hd ** -0.5, window=window, blocks_per_step=blocks_per_step,
        interpret=caps.interpret)
    return out.reshape(b, 1, h, hd)
