"""Version-compat shims for Pallas TPU API drift.

The Pallas TPU namespace renamed several symbols across jax releases
(``TPUCompilerParams`` -> ``CompilerParams``, and the older
``dimension_semantics=`` kwarg moved between positional conventions).  Every
kernel in this repo goes through this module instead of touching
``pltpu.CompilerParams`` directly, so a jax upgrade is a one-file change.

Resolved at import time (cheap, and failures surface immediately):

  * :data:`CompilerParams`  — the compiler-params class for ``pallas_call``.
  * :func:`compiler_params` — build a params object from keyword arguments,
    dropping kwargs the installed class does not know about (forward/backward
    tolerant).

Plus the interpret-mode policy every kernel wrapper shares:

  * :func:`default_interpret` / :func:`resolve_interpret` — off-TPU backends
    run ``pallas_call(interpret=True)``, which is how CPU CI exercises every
    kernel (flash_attn, paged_attn, bitplane_mac) on each PR instead of only
    on TPU hardware.
"""
from __future__ import annotations

import inspect

import jax
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.7 exposes ``CompilerParams``; 0.4.x-0.6.x call it
# ``TPUCompilerParams``.  Resolve whichever exists.
if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
elif hasattr(pltpu, "TPUCompilerParams"):
    CompilerParams = pltpu.TPUCompilerParams
else:  # pragma: no cover - ancient jax; kernels would not work anyway
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported")

_ACCEPTED = frozenset(inspect.signature(CompilerParams).parameters)


def compiler_params(**kw):
    """``CompilerParams(**kw)`` with unknown kwargs silently dropped.

    Lets call-sites pass the superset of tuning knobs they want; whatever the
    installed jax supports takes effect.
    """
    return CompilerParams(**{k: v for k, v in kw.items() if k in _ACCEPTED})


def default_interpret() -> bool:
    """True off-TPU: Mosaic only targets TPU, so every other backend runs the
    kernels through the Pallas interpreter (bit-faithful, portable CI)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """The ``interpret=None`` convention shared by all kernel ``ops`` wrappers:
    ``None`` defers to :func:`default_interpret`, an explicit bool wins."""
    return default_interpret() if interpret is None else interpret
