"""Version-compat shims for Pallas TPU API drift + kernel capability probing.

The Pallas TPU namespace renamed several symbols across jax releases
(``TPUCompilerParams`` -> ``CompilerParams``, and the older
``dimension_semantics=`` kwarg moved between positional conventions).  Every
kernel in this repo goes through this module instead of touching
``pltpu.CompilerParams`` directly, so a jax upgrade is a one-file change.

Resolved at import time (cheap, and failures surface immediately):

  * :data:`CompilerParams`  — the compiler-params class for ``pallas_call``.
  * :func:`compiler_params` — build a params object from keyword arguments,
    dropping kwargs the installed class does not know about (forward/backward
    tolerant).

Plus the ONE capability helper every kernel wrapper queries
(:func:`kernel_caps`), consolidating two orthogonal detections:

  * **interpret** — off-TPU backends run ``pallas_call(interpret=True)``,
    which is how CPU CI exercises every kernel (flash_attn, paged_attn,
    bitplane_mac, imc_mac, rbl_decode) on each PR instead of only on TPU.
  * **prng**      — whether an in-kernel PRNG is usable for the noisy
    kernels.  The interpreter has NO lowering for the Mosaic hardware PRNG
    (``pltpu.prng_seed`` raises ``NotImplementedError`` on CPU), so
    interpret-mode kernels fall back to a stateless counter-hash PRNG
    (:func:`repro.kernels.common.make_normal_sampler`) which runs anywhere;
    the compiled TPU path requires the ``pltpu.prng_seed`` /
    ``prng_random_bits`` primitives.  ``prng=False`` therefore only happens
    on a compiled-TPU build of jax too old to expose them — the one case
    where a noisy kernel wrapper must warn and fall back to the jnp engine.
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass

import jax
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.7 exposes ``CompilerParams``; 0.4.x-0.6.x call it
# ``TPUCompilerParams``.  Resolve whichever exists.
if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
elif hasattr(pltpu, "TPUCompilerParams"):
    CompilerParams = pltpu.TPUCompilerParams
else:  # pragma: no cover - ancient jax; kernels would not work anyway
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported")

_ACCEPTED = frozenset(inspect.signature(CompilerParams).parameters)

# Mosaic hardware PRNG primitives (the compiled-TPU noisy fast path).
HAS_TPU_PRNG = (hasattr(pltpu, "prng_seed")
                and hasattr(pltpu, "prng_random_bits"))


def compiler_params(**kw):
    """``CompilerParams(**kw)`` with unknown kwargs silently dropped.

    Lets call-sites pass the superset of tuning knobs they want; whatever the
    installed jax supports takes effect.
    """
    return CompilerParams(**{k: v for k, v in kw.items() if k in _ACCEPTED})


@dataclass(frozen=True)
class KernelCaps:
    """What the resolved execution mode of a kernel can do.

    interpret — this call runs through the Pallas interpreter.
    prng      — an in-kernel PRNG is available for noisy kernels: always in
                interpret mode (counter-hash fallback), and in compiled mode
                iff the installed jax exposes the Mosaic PRNG primitives.
    """

    interpret: bool
    prng: bool


def kernel_caps(interpret: bool | None = None) -> KernelCaps:
    """Resolve one kernel call's capabilities (the five ops.py entry points).

    ``interpret=None`` defers to :func:`default_interpret`; an explicit bool
    wins.  PRNG capability is derived from the SAME resolution, so interpret
    detection and PRNG detection can never disagree about which engine a
    noisy call actually runs on.
    """
    it = default_interpret() if interpret is None else interpret
    return KernelCaps(interpret=it, prng=it or HAS_TPU_PRNG)


def default_interpret() -> bool:
    """True off-TPU: Mosaic only targets TPU, so every other backend runs the
    kernels through the Pallas interpreter (bit-faithful, portable CI)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """The ``interpret=None`` convention shared by all kernel ``ops`` wrappers:
    ``None`` defers to :func:`default_interpret`, an explicit bool wins."""
    return kernel_caps(interpret).interpret
