"""Pallas TPU kernel: grouped binary MAC with in-loop analog RBL decode.

Hardware-faithful emulation of the paper's evaluation pipeline for one
bit-plane pair: the K dimension is tiled into groups of ``rows`` (8 — one SRAM
column-load each); each group's binary MAC count is pushed through the
charge-sharing voltage model and the comparator thermometer decode *before*
the digital shift-accumulate, exactly as the macro would.

  out[m, n] = sum_g decode( V( sum_{r<rows} a[m, g*rows+r] * w[g*rows+r, n] ) )

The decode is algebraically the identity for noise-free counts, but this
kernel keeps the analog stage in-loop so threshold re-tuning / reduced-margin
studies (paper §III-F scaling) run at kernel speed instead of pure-jnp speed.

Implementation notes (TPU adaptation):
  * group MACs are a G-batched (bm, rows) x (rows, bn) dot_general — small-K
    matmuls; the MXU eats them as a batched contraction.  This path trades
    MXU efficiency for per-group visibility; the *exact* path (imc_mac) is
    the production-speed collapse of the same math.
  * V(k) uses the fitted two-regime physics (exp/linear) on the VPU;
    comparator bank = 8 broadcast compares + sum, i.e. pure vector ops.
  * thresholds arrive as a (1, rows) block so corner-re-tuned references
    (paper §IV-C) are a data, not code, change.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import constants as C
from repro.kernels.common import decode_counts
from repro.kernels.compat import compiler_params


def _make_kernel(rows: int, bk: int):
    groups = bk // rows

    def kernel(a_ref, b_ref, thr_ref, o_ref, acc_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        bm = a_ref.shape[0]
        bn = b_ref.shape[1]
        a = a_ref[...].astype(jnp.float32).reshape(bm, groups, rows)
        b = b_ref[...].astype(jnp.float32).reshape(groups, rows, bn)
        # counts[g, m, n] = sum_r a[m, g, r] * b[g, r, n]
        counts = jax.lax.dot_general(
            a, b, (((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32)
        dec = decode_counts(counts, thr_ref[...], rows)
        acc_ref[...] += jnp.sum(dec, axis=0)

        @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
        def _flush():
            o_ref[...] = acc_ref[...].astype(jnp.int32)

    return kernel


@functools.partial(jax.jit, static_argnames=("rows", "bm", "bn", "bk",
                                             "interpret"))
def rbl_decode_mac_raw(a_bits, w_bits, thresholds, *, rows: int = C.ROWS,
                       bm: int = 128, bn: int = 128, bk: int = 256,
                       interpret: bool = False):
    """Grouped-decode binary MAC.

    a_bits: int8[M, K] in {0,1}; w_bits: int8[K, N] in {0,1};
    thresholds: float32[rows] descending comparator references.
    M, N, K must be divisible by (bm, bn, bk) and bk by rows (ops.py pads).
    Returns int32[M, N] = sum of per-group decoded counts.
    """
    m, k = a_bits.shape
    k2, n = w_bits.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bk % rows == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _make_kernel(rows, bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, rows), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_bits.astype(jnp.int8), w_bits.astype(jnp.int8),
      jnp.asarray(thresholds, jnp.float32).reshape(1, rows))
