"""Pure-jnp oracle for the rbl_decode kernel (built on repro.core)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import constants as C
from repro.core.bitserial import group_counts
from repro.core.decoder import decode_voltage
from repro.core.rbl import rbl_voltage


def rbl_decode_mac_ref(a_bits, w_bits, *, rows: int = C.ROWS,
                       mode: str = "physics"):
    """sum_g decode(V(count_g)) using the core reference path."""
    counts = group_counts(a_bits, w_bits, rows)  # [..., G, N]
    v = rbl_voltage(counts.astype(jnp.float32), rows=rows, mode=mode)
    dec = decode_voltage(v, rows=rows, mode=mode)
    return jnp.sum(dec, axis=-2).astype(jnp.int32)
