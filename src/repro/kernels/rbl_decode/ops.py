"""jit'd public wrapper for the rbl_decode kernel (padding, thresholds)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import constants as C
from repro.core.decoder import thresholds as core_thresholds
from repro.kernels.rbl_decode.rbl_decode import rbl_decode_mac_raw
from repro.kernels.compat import kernel_caps


@functools.partial(jax.jit, static_argnames=("rows", "bm", "bn", "bk",
                                             "interpret"))
def rbl_decode_mac(a_bits, w_bits, thr=None, *, rows: int = C.ROWS,
                   bm: int = 128, bn: int = 128, bk: int = 256,
                   interpret: bool | None = None):
    """Grouped analog-decode binary MAC for arbitrary shapes.

    Leading batch dims of ``a_bits`` flatten into M.  ``thr`` defaults to the
    physics-model comparator references for ``rows`` (re-tunable, §IV-C).
    """
    interpret = kernel_caps(interpret).interpret
    if thr is None:
        thr = core_thresholds(rows, mode="physics")
    batch = a_bits.shape[:-1]
    m = 1
    for b in batch:
        m *= b
    k = a_bits.shape[-1]
    n = w_bits.shape[-1]
    a2 = a_bits.reshape(m, k)
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    if pm or pk:
        a2 = jnp.pad(a2, ((0, pm), (0, pk)))
    if pk or pn:
        w_bits = jnp.pad(w_bits, ((0, pk), (0, pn)))
    out = rbl_decode_mac_raw(a2, w_bits, thr, rows=rows, bm=bm, bn=bn, bk=bk,
                             interpret=interpret)
    return out[:m, :n].reshape(*batch, n)
