"""Kernel autotuner: search-measure-cache for Pallas tile geometry.

The kernels ship with hardcoded tile guesses (``bitplane_mac``'s
(bm, bn, bk) = (128, 128, 256), ``paged_attn``'s one pool panel per grid
step).  This module replaces guesses with measurements:

  * :func:`tune` times REAL ``pallas_call``s over a candidate space and
    caches the winner per ``(kernel, shape-bucket, dtype, backend)``.
  * :func:`lookup` is what the kernel ``ops`` wrappers call at trace time:
    defaults <- cached winner <- ``REPRO_TUNE_<KERNEL>`` env pin, most
    specific wins.  A lookup NEVER runs trials — tuning is explicit
    (``benchmarks.run --autotune`` or :func:`tune` directly).
  * the cache is a JSON file committed to the repo
    (``src/repro/kernels/autotune/tuned.json``), so CI runs are
    deterministic and trial-free; re-tuning on new hardware rewrites it
    (``REPRO_AUTOTUNE_CACHE`` points elsewhere without touching the
    committed file).
  * :func:`geometry_token` is a tiny hashable snapshot of "which geometry
    would lookups resolve to right now" — the launch Engine folds it into
    its compiled-step cache key, so a re-tune (or an env pin change) can
    never reuse a stale executable, while a stable cache keeps steady state
    at zero retraces.

Telemetry: every measured candidate increments ``autotune.trials`` and each
``tune`` call runs under an ``autotune.tune`` span — a warm (fully cached)
run is observable as zero trials.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

from repro.telemetry import clock, get_registry, span

# Hardcoded fallbacks == the pre-autotuner kernel defaults, so a missing
# cache entry reproduces historical behavior exactly.
DEFAULTS: Dict[str, Dict[str, int]] = {
    "bitplane_mac": {"bm": 128, "bn": 128, "bk": 256},
    "paged_attn": {"bps": 1},
}

_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
_ENV_PIN_PREFIX = "REPRO_TUNE_"  # REPRO_TUNE_BITPLANE_MAC="bm=64,bn=128,bk=128"

# Bumped on every cache mutation (store/load/clear) — the cheap global the
# geometry token watches so Engine step caches notice re-tunes.
_VERSION = 0


def _bump() -> None:
    global _VERSION
    _VERSION += 1


def default_cache_path() -> str:
    env = os.environ.get(_ENV_CACHE)
    if env:
        return env
    return os.path.join(os.path.dirname(__file__), "tuned.json")


def _pow2_bucket(v: int) -> int:
    p = 1
    while p < v:
        p *= 2
    return p


def shape_bucket(shapes: Dict[str, int]) -> str:
    """Canonical bucket string: each dim rounded up to a power of two.

    Nearby shapes share one tuned geometry (tile choice is insensitive to
    e.g. m=500 vs m=512), keeping the cache small and lookups exact-match.
    """
    return "_".join(f"{k}{_pow2_bucket(int(v))}"
                    for k, v in sorted(shapes.items()))


def backend_key(interpret: bool) -> str:
    """Cache axis for the execution engine: interpret mode is its own
    backend (interpreter-optimal tiles are NOT Mosaic-optimal tiles)."""
    import jax

    b = jax.default_backend()
    return f"{b}+interpret" if interpret else b


def _parse_pin(text: str) -> Dict[str, int]:
    out = {}
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        k, _, v = part.partition("=")
        out[k.strip()] = int(v)
    return out


def env_pins() -> Dict[str, Dict[str, int]]:
    """{kernel: geometry} pinned via REPRO_TUNE_<KERNEL> env vars."""
    pins = {}
    for name, val in os.environ.items():
        if name.startswith(_ENV_PIN_PREFIX) and name != _ENV_CACHE:
            kernel = name[len(_ENV_PIN_PREFIX):].lower()
            try:
                pins[kernel] = _parse_pin(val)
            except ValueError:
                raise ValueError(
                    f"malformed {name}={val!r}; expected 'k=v,k=v' ints")
    return pins


class AutotuneCache:
    """Persistent JSON store of tuned geometries.

    Entries: ``{key: {"geometry": {...}, "us": float, "trials": int}}`` with
    ``key = kernel|bucket|dtype|backend``.  ``store`` persists immediately
    (atomic-enough single write) and bumps the global geometry version.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self.entries: Dict[str, Dict] = {}
        if os.path.exists(self.path):
            self.load()

    @staticmethod
    def key(kernel: str, bucket: str, dtype: str, backend: str) -> str:
        return "|".join((kernel, bucket, dtype, backend))

    def load(self) -> None:
        with open(self.path) as f:
            rec = json.load(f)
        self.entries = rec.get("entries", {})
        _bump()

    def save(self) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"format": 1, "entries": self.entries}, f, indent=1,
                      sort_keys=True)
            f.write("\n")

    def lookup(self, kernel: str, bucket: str, dtype: str,
               backend: str) -> Optional[Dict[str, int]]:
        e = self.entries.get(self.key(kernel, bucket, dtype, backend))
        return dict(e["geometry"]) if e else None

    def store(self, kernel: str, bucket: str, dtype: str, backend: str,
              geometry: Dict[str, int], us: float, trials: int) -> None:
        self.entries[self.key(kernel, bucket, dtype, backend)] = {
            "geometry": dict(geometry), "us": round(float(us), 2),
            "trials": int(trials)}
        self.save()
        _bump()


_CACHE: Optional[AutotuneCache] = None


def get_cache() -> AutotuneCache:
    global _CACHE
    if _CACHE is None or _CACHE.path != default_cache_path():
        _CACHE = AutotuneCache()
    return _CACHE


def set_cache(cache: Optional[AutotuneCache]) -> None:
    """Swap the process cache (tests; ``None`` re-resolves from env)."""
    global _CACHE
    _CACHE = cache
    _bump()


def geometry_token() -> Tuple:
    """Hashable snapshot of the ambient tuning state.

    Equal tokens guarantee every ``lookup`` resolves identically, so
    compiled steps keyed on the token retrace exactly when a re-tune (or a
    pin change) could alter kernel geometry — and never otherwise.
    """
    pins = tuple(sorted((k, tuple(sorted(v.items())))
                        for k, v in env_pins().items()))
    return (_VERSION, pins)


def lookup(kernel: str, shapes: Dict[str, int], *, dtype: str = "int8",
           interpret: bool = False,
           cache: Optional[AutotuneCache] = None) -> Dict[str, int]:
    """Resolve geometry for one kernel call (trace-time; never measures).

    Precedence: :data:`DEFAULTS` <- cached tune winner <- env pin.
    """
    geom = dict(DEFAULTS.get(kernel, {}))
    c = cache if cache is not None else get_cache()
    hit = c.lookup(kernel, shape_bucket(shapes), dtype,
                   backend_key(interpret))
    if hit:
        geom.update(hit)
    pin = env_pins().get(kernel)
    if pin:
        geom.update(pin)
    return geom


# ------------------------------------------------------------- measurement
def _time_call(fn, *args, repeats: int, warmup: int, **kw) -> float:
    """Best-of wall time per call in microseconds (device-complete)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    best = float("inf")
    for _ in range(repeats):
        t0 = clock()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, clock() - t0)
    return best * 1e6


def _measure_bitplane_mac(shapes: Dict[str, int], geom: Dict[str, int],
                          interpret: bool, repeats: int, warmup: int) -> float:
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.bitplane_mac.ops import bitplane_mac

    m, k, n = shapes["m"], shapes["k"], shapes["n"]
    ba, bw = shapes.get("ba", 8), shapes.get("bw", 8)
    rng = np.random.default_rng(0)
    ua = jnp.asarray(rng.integers(0, 1 << ba, size=(m, k)).astype(np.int32))
    uw = jnp.asarray(rng.integers(0, 1 << bw, size=(k, n)).astype(np.int32))
    return _time_call(bitplane_mac, ua, uw, bits_a=ba, bits_w=bw,
                      bm=geom["bm"], bn=geom["bn"], bk=geom["bk"],
                      interpret=interpret, repeats=repeats, warmup=warmup)


def _measure_paged_attn(shapes: Dict[str, int], geom: Dict[str, int],
                        interpret: bool, repeats: int, warmup: int) -> float:
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.paged_attn.ops import paged_attention

    b = shapes.get("b", 4)
    kv = shapes.get("kv", 2)
    h = kv * shapes.get("rep", 2)
    hd = shapes.get("hd", 64)
    bs = shapes.get("bs", 16)
    mb = shapes.get("mb", 8)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)).astype(np.float32))
    # int8 pools + scale pools: the serving quantized layout (and the cache
    # cell's dtype key).
    pools = rng.integers(-127, 128, size=(2, b * mb, bs, kv, hd))
    kp, vp = (jnp.asarray(p, jnp.int8) for p in pools)
    sc = jnp.asarray(rng.uniform(0.01, 0.02, size=(b * mb, bs, kv)),
                     jnp.float32)
    table = jnp.arange(b * mb, dtype=jnp.int32).reshape(b, mb)
    pos = jnp.full((b,), mb * bs - 1, jnp.int32)
    return _time_call(paged_attention, q, kp, vp, table, pos, k_scale=sc,
                      v_scale=sc, impl="pallas",
                      blocks_per_step=geom["bps"], interpret=interpret,
                      repeats=repeats, warmup=warmup)


_MEASURE = {
    "bitplane_mac": _measure_bitplane_mac,
    "paged_attn": _measure_paged_attn,
}

# Default candidate spaces (small on purpose: tune() is explicit, and a
# committed cache makes CI trial-free).
SPACES: Dict[str, List[Dict[str, int]]] = {
    "bitplane_mac": [
        {"bm": bm, "bn": bn, "bk": bk}
        for bm in (64, 128) for bn in (64, 128) for bk in (128, 256)
    ],
    "paged_attn": [{"bps": bps} for bps in (1, 2, 4)],
}


def tune(kernel: str, shapes: Dict[str, int],
         space: Optional[List[Dict[str, int]]] = None, *,
         dtype: str = "int8", interpret: Optional[bool] = None,
         repeats: int = 3, warmup: int = 1,
         cache: Optional[AutotuneCache] = None,
         registry=None) -> Dict[str, int]:
    """Measure every candidate and cache the winner; returns its geometry.

    Already-cached (kernel, bucket, dtype, backend) cells return instantly
    with ZERO trials — delete the cache entry (or point
    ``REPRO_AUTOTUNE_CACHE`` at a fresh file) to force a re-tune.
    """
    from repro.kernels.compat import kernel_caps

    it = kernel_caps(interpret).interpret
    c = cache if cache is not None else get_cache()
    reg = registry if registry is not None else get_registry()
    bucket = shape_bucket(shapes)
    backend = backend_key(it)
    cached = c.lookup(kernel, bucket, dtype, backend)
    if cached is not None:
        return cached
    measure = _MEASURE[kernel]
    space = space if space is not None else SPACES[kernel]
    if not space:
        raise ValueError(f"empty candidate space for {kernel!r}")
    trials = reg.counter("autotune.trials")
    best_geom, best_us = None, float("inf")
    with span("autotune.tune", kernel=kernel, bucket=bucket,
              backend=backend):
        for cand in space:
            geom = {**DEFAULTS.get(kernel, {}), **cand}
            us = measure(shapes, geom, it, repeats, warmup)
            trials.inc()
            reg.histogram("autotune.trial_us").observe(us)
            if us < best_us:
                best_geom, best_us = geom, us
    c.store(kernel, bucket, dtype, backend, best_geom, best_us, len(space))
    return dict(best_geom)


# The reduced-arch serving GEMMs the ``sim/pallas+noise`` serve bench rows
# push through the fabric (qkv/o/mlp projections at decode m=4 slots and
# prefill m=16 bucket), and a small-tile space for them: at these shapes the
# win is minimizing padded volume, not MXU occupancy — on the interpreter
# the big default tiles are ~100x slower.
SERVE_CELLS: List[Dict[str, int]] = [
    {"m": m, "k": k, "n": n, "ba": 4, "bw": 4}
    for m in (4, 16)
    for k, n in ((64, 32), (64, 64), (64, 128), (128, 64))
]
SERVE_SPACE: List[Dict[str, int]] = [
    {"bm": 8, "bn": 32, "bk": 64},
    {"bm": 16, "bn": 64, "bk": 64},
    {"bm": 8, "bn": 64, "bk": 128},
]


def tune_standard(smoke: bool = True, registry=None) -> List[Tuple[str, str,
                                                                   Dict, str]]:
    """The bench CLI's ``--autotune`` entry: tune the serving-relevant cells.

    Covers the paper's 8x8 macro / 8-bit GEMM shape for ``bitplane_mac``,
    the reduced-arch serve-projection buckets (:data:`SERVE_CELLS`, what the
    noisy-pallas serve bench rows hit), and the pool-panel sweep for
    ``paged_attn``.  Returns (kernel, bucket, geometry, backend) rows for
    the CSV.
    """
    from repro.kernels.compat import kernel_caps

    backend = backend_key(kernel_caps(None).interpret)
    rows = []
    bitplane_shapes = [{"m": 64, "k": 512, "n": 64, "ba": 8, "bw": 8}]
    paged_shapes = [{"b": 4, "kv": 2, "rep": 2, "hd": 64, "bs": 16, "mb": 8}]
    if not smoke:
        bitplane_shapes.append(
            {"m": 256, "k": 1024, "n": 256, "ba": 8, "bw": 8})
        paged_shapes.append(
            {"b": 8, "kv": 4, "rep": 4, "hd": 64, "bs": 16, "mb": 32})
    space_bp = SPACES["bitplane_mac"]
    if smoke:  # interpreter trials are slow; keep the smoke space tiny
        space_bp = [g for g in space_bp if g["bm"] == g["bn"]]
    for shapes in bitplane_shapes:
        geom = tune("bitplane_mac", shapes, space_bp, registry=registry)
        rows.append(("bitplane_mac", shape_bucket(shapes), geom, backend))
    for shapes in SERVE_CELLS:
        geom = tune("bitplane_mac", shapes, SERVE_SPACE, registry=registry)
        rows.append(("bitplane_mac", shape_bucket(shapes), geom, backend))
    for shapes in paged_shapes:
        geom = tune("paged_attn", shapes, registry=registry)
        rows.append(("paged_attn", shape_bucket(shapes), geom, backend))
    return rows
