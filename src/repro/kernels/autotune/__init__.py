"""Kernel autotuner: measured, cached Pallas tile geometry.

See :mod:`repro.kernels.autotune.tuner` for the design.  The committed
``tuned.json`` beside this file is the CI-deterministic cache; point
``REPRO_AUTOTUNE_CACHE`` elsewhere to tune without touching it, and pin a
kernel's geometry outright with ``REPRO_TUNE_<KERNEL>="bm=64,bn=64,bk=128"``.
"""
from repro.kernels.autotune.tuner import (DEFAULTS, SPACES, AutotuneCache,
                                          backend_key, default_cache_path,
                                          env_pins, geometry_token, get_cache,
                                          lookup, set_cache, shape_bucket,
                                          tune, tune_standard)

__all__ = [
    "DEFAULTS", "SPACES", "AutotuneCache", "backend_key",
    "default_cache_path", "env_pins", "geometry_token", "get_cache",
    "lookup", "set_cache", "shape_bucket", "tune", "tune_standard",
]
