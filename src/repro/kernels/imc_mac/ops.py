"""jit'd public wrappers for the imc_mac kernel (padding + backend dispatch).

``interpret`` defaults to True off-TPU so the kernel body executes (and is
tested) on CPU; on TPU it compiles to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.imc_mac.imc_mac import imc_mac_dequant_raw, imc_mac_raw
from repro.kernels.compat import kernel_caps


def _pad2(x, mult0, mult1):
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def imc_mac(qa, qw, *, bm: int = 128, bn: int = 128, bk: int = 128,
            interpret: bool | None = None):
    """int8 GEMM with int32 accumulation; arbitrary (even ragged) shapes.

    Leading batch dims of ``qa`` are flattened into M.
    """
    interpret = kernel_caps(interpret).interpret
    batch = qa.shape[:-1]
    m = 1
    for b in batch:
        m *= b
    k = qa.shape[-1]
    n = qw.shape[-1]
    qa2 = _pad2(qa.reshape(m, k), bm, bk)
    qw2 = _pad2(qw, bk, bn)
    out = imc_mac_raw(qa2, qw2, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n].reshape(*batch, n)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def imc_mac_dequant(qa, qw, scale_a, scale_w, *, bm: int = 128, bn: int = 128,
                    bk: int = 128, interpret: bool | None = None):
    """Fused int8 GEMM + per-channel dequant -> float32."""
    interpret = kernel_caps(interpret).interpret
    batch = qa.shape[:-1]
    m = 1
    for b in batch:
        m *= b
    k = qa.shape[-1]
    n = qw.shape[-1]
    qa2 = _pad2(qa.reshape(m, k), bm, bk)
    qw2 = _pad2(qw, bk, bn)
    sw = jnp.pad(jnp.asarray(scale_w, jnp.float32).reshape(-1),
                 (0, qw2.shape[1] - n))
    out = imc_mac_dequant_raw(qa2, qw2, scale_a, sw, bm=bm, bn=bn, bk=bk,
                              interpret=interpret)
    return out[:m, :n].reshape(*batch, n)
