"""Pallas TPU kernel: IMC-equivalent int8 MAC (quantized GEMM).

This is the *exact digital equivalent* of the paper's bit-serial SRAM MAC:
because the thermometer decode is exact on [0, rows], the per-8-row group
counts telescope and the whole bit-plane pyramid collapses to an int8 x int8
integer matmul (see core/bitserial.py for the proof-by-construction).  On TPU
that is MXU-native work; this kernel supplies the blocked VMEM implementation
with int32 accumulation and optional fused per-channel dequantization.

Tiling: grid (M/bm, N/bn, K/bk), K innermost ("arbitrary"), VMEM int32
accumulator scratch per (bm, bn) tile.  MXU-aligned defaults bm=bn=bk=128
(int8 MXU likes 128x128; K-blocks stream through VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _mac_kernel(a_ref, b_ref, o_ref, acc_ref):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _mac_dequant_kernel(a_ref, b_ref, sa_ref, sw_ref, o_ref, acc_ref):
    """As _mac_kernel but flushes float32 acc * scale_a * scale_w[n]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * sa_ref[0, 0]
                      * sw_ref[...].astype(jnp.float32))


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def imc_mac_raw(qa, qw, *, bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: bool = False):
    """int8[M,K] x int8[K,N] -> int32[M,N].  Shapes must be block-divisible
    (the ops.py wrapper pads)."""
    m, k = qa.shape
    k2, n = qw.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mac_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qa.astype(jnp.int8), qw.astype(jnp.int8))


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def imc_mac_dequant_raw(qa, qw, scale_a, scale_w, *, bm: int = 128,
                        bn: int = 128, bk: int = 128,
                        interpret: bool = False):
    """Fused dequant: float32[M,N] = (qa @ qw) * scale_a * scale_w[None, :].

    scale_a: float32 scalar (per-tensor activation scale), passed via a (1,1)
    SMEM-style block; scale_w: float32[N] per-output-channel scales.
    """
    m, k = qa.shape
    k2, n = qw.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mac_dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qa.astype(jnp.int8), qw.astype(jnp.int8),
      jnp.asarray(scale_a, jnp.float32).reshape(1, 1),
      jnp.asarray(scale_w, jnp.float32).reshape(1, n))
