"""Pure-jnp oracle for the imc_mac kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def imc_mac_ref(qa, qw):
    """int8[M,K] x int8[K,N] -> int32[M,N]."""
    return jax.lax.dot_general(
        qa.astype(jnp.int8), qw.astype(jnp.int8),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def imc_mac_dequant_ref(qa, qw, scale_a, scale_w):
    acc = imc_mac_ref(qa, qw).astype(jnp.float32)
    return acc * jnp.asarray(scale_a, jnp.float32) * jnp.asarray(
        scale_w, jnp.float32)[None, :]
