"""Shared in-kernel analog-path helpers for the Pallas IMC kernels.

``rbl_decode`` (one bit-plane pair) and ``bitplane_mac`` (the full pyramid)
evaluate the identical decode stage in-register; keeping it here means a
threshold tie-break fix or physics recalibration lands in both kernels at
once.  Pure jnp on values (not refs), so it is safe inside kernel bodies and
in interpret mode alike.

This module also owns the **in-kernel PRNG** the noisy kernels draw from
(:func:`make_normal_sampler`): on the compiled TPU path it seeds the Mosaic
per-core hardware PRNG (``pltpu.prng_seed`` / ``prng_random_bits``); in
interpret mode — where those primitives have no CPU lowering — it substitutes
a stateless murmur-mixed counter PRNG over (seed, draw index, element index).
Both feed Box-Muller, so either path yields f32 N(0,1) variates.  The two
streams are necessarily DIFFERENT bit patterns, which is why noisy-kernel
parity against the keyed jnp oracle is pinned on moments/quantiles, never on
bit identity.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

from repro.core import constants as C

_PHI32 = 0x9E3779B9  # golden-ratio odd constant (Weyl increment / mixing)
_INV_2_24 = float(2.0 ** -24)


def counts_to_voltage(k_float, rows: int):
    """MAC count (possibly fractional: mismatch) -> V_RBL, two-regime physics.

    The in-register mirror of :func:`repro.core.rbl.rbl_voltage_physics` at
    the calibrated 0.7 ns window, with the §III-F capacitance scaling for
    non-8-row geometries.
    """
    u = C.U_LIN * (C.ROWS / rows)
    x = k_float * u
    lin = C.V0_LEAK - x
    x_tri = jnp.maximum(x - (C.V0_LEAK - C.VD_SAT), 0.0)
    tri = C.VD_SAT * jnp.exp(-x_tri / C.VD_SAT)
    return jnp.where(lin >= C.VD_SAT, lin, tri)


def decode_counts(k_float, thr, rows: int):
    """Counts -> V_RBL (two-regime physics) -> comparator decode -> counts.

    ``thr`` is a (1, rows) block of descending comparator references;
    count = number of thresholds >= V, matching ``decoder.decode_voltage``.
    """
    v = counts_to_voltage(k_float, rows)
    # comparator bank: count = number of thresholds >= V (thr descending)
    dec = jnp.zeros_like(k_float)
    for i in range(rows):  # static unroll: rows is small (8)
        dec = dec + (v <= thr[0, i]).astype(jnp.float32)
    return dec


def decode_counts_noisy(k_float, thr, rows: int, normal, *,
                        mismatch_sigma=None, comparator_offset_sigma=None):
    """The noisy sibling of :func:`decode_counts` — the NoiseSpec path.

    Device mismatch perturbs the effective count before the voltage map
    (stddev ``mismatch_sigma * sqrt(count)``, matching
    ``montecarlo.mc_count_noise``); comparator offset perturbs each
    reference independently per element per comparator (matching
    ``decoder.thermometer_code``).  ``normal(shape)`` is a sampler from
    :func:`make_normal_sampler` — every call site draws a fresh stream.
    """
    if mismatch_sigma:
        k_float = k_float + mismatch_sigma * jnp.sqrt(
            jnp.maximum(k_float, 0.0)) * normal(k_float.shape)
    v = counts_to_voltage(k_float, rows)
    dec = jnp.zeros_like(v)
    for i in range(rows):  # static unroll: rows is small (8)
        t = thr[0, i]
        if comparator_offset_sigma:
            t = t + comparator_offset_sigma * normal(v.shape)
        dec = dec + (v <= t).astype(jnp.float32)
    return dec


def _mix32(x):
    """murmur3 fmix32: bijective avalanche mix on uint32 lanes."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _bits_to_uniform(bits):
    """uint32/int32 random bits -> f32 uniform in [0, 1) (24-bit mantissa)."""
    top = jax.lax.shift_right_logical(bits.astype(jnp.uint32),
                                      jnp.full(bits.shape, 8, jnp.uint32))
    return top.astype(jnp.float32) * _INV_2_24


def make_normal_sampler(seeds, *, hw_prng: bool):
    """Build a ``normal(shape) -> f32 N(0,1)`` sampler for a kernel body.

    ``seeds`` — tuple of int32 scalars identifying the stream (base key words
    + a flattened grid-step index), so every (M-tile, N-tile, plane-pair,
    K-group) grid position draws an independent stream regardless of
    execution order.

    ``hw_prng=True``  — compiled TPU path: seed the Mosaic per-core PRNG once
    (re-seeded at every grid step from the step-folded seeds, so megacore
    partitioning of the parallel axes cannot correlate streams), then draw
    sequentially with ``prng_random_bits``.

    ``hw_prng=False`` — interpret-mode fallback: a stateless counter PRNG.
    Each call mixes (seed, per-call salt, element linear index) through two
    murmur rounds; no sequential state, so it is order-independent and runs
    on any backend.

    Both paths map bits -> [0,1) uniforms -> Box-Muller normals.  The draw
    counter is advanced at Python level during tracing (the kernel body
    traces once), giving each call site a distinct static salt.
    """
    counter = [0]
    if hw_prng:
        pltpu.prng_seed(*seeds)

        def uniforms(shape, salt):
            del salt  # the hardware stream is sequential
            return _bits_to_uniform(pltpu.prng_random_bits(shape))
    else:
        mixed = jnp.uint32(0)
        for s in seeds:
            word = jax.lax.bitcast_convert_type(
                jnp.asarray(s, jnp.int32), jnp.uint32)
            mixed = _mix32(mixed ^ word)

        def uniforms(shape, salt):
            lin = jnp.zeros(shape, jnp.uint32)
            stride = 1
            for d in reversed(range(len(shape))):
                lin = lin + jax.lax.broadcasted_iota(
                    jnp.uint32, shape, d) * jnp.uint32(stride)
                stride *= shape[d]
            x = mixed + jnp.uint32(salt) * jnp.uint32(_PHI32)
            return _bits_to_uniform(_mix32(_mix32(
                lin * jnp.uint32(_PHI32) + x)))

    def normal(shape):
        salt = counter[0]
        counter[0] += 2
        u1 = uniforms(shape, salt)
        u2 = uniforms(shape, salt + 1)
        # Box-Muller; 1-u1 in (2^-24, 1], so the log is always finite.
        r = jnp.sqrt(-2.0 * jnp.log(1.0 - u1))
        return r * jnp.cos((2.0 * math.pi) * u2)

    return normal
