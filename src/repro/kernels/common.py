"""Shared in-kernel analog-path helpers for the Pallas IMC kernels.

``rbl_decode`` (one bit-plane pair) and ``bitplane_mac`` (the full pyramid)
evaluate the identical decode stage in-register; keeping it here means a
threshold tie-break fix or physics recalibration lands in both kernels at
once.  Pure jnp on values (not refs), so it is safe inside kernel bodies and
in interpret mode alike.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import constants as C


def decode_counts(k_float, thr, rows: int):
    """Counts -> V_RBL (two-regime physics) -> comparator decode -> counts.

    ``thr`` is a (1, rows) block of descending comparator references;
    count = number of thresholds >= V, matching ``decoder.decode_voltage``.
    """
    u = C.U_LIN * (C.ROWS / rows)
    x = k_float * u
    lin = C.V0_LEAK - x
    x_tri = jnp.maximum(x - (C.V0_LEAK - C.VD_SAT), 0.0)
    tri = C.VD_SAT * jnp.exp(-x_tri / C.VD_SAT)
    v = jnp.where(lin >= C.VD_SAT, lin, tri)
    # comparator bank: count = number of thresholds >= V (thr descending)
    dec = jnp.zeros_like(k_float)
    for i in range(rows):  # static unroll: rows is small (8)
        dec = dec + (v <= thr[0, i]).astype(jnp.float32)
    return dec
