"""Public wrapper: GQA-aware causal flash attention over (B, S, H, hd)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.compat import kernel_caps
from repro.kernels.flash_attn.flash_attn import flash_attention_raw


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, window: int = 0, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None):
    """Causal self-attention. q: (B, S, H, hd); k/v: (B, S, KV, hd).

    GQA: KV heads are expanded to H (wrapper-level repeat; the kernel sees
    flat (B*H, S, hd) panels).  S is padded to the block size; padded keys
    are masked inside the kernel via the valid-length closure.
    """
    interpret = kernel_caps(interpret).interpret
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    bq_eff = min(bq, max(s, 8))
    bk_eff = min(bk, max(s, 8))
    pad = (-s) % max(bq_eff, bk_eff)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    out = flash_attention_raw(qf, kf, vf, scale=hd ** -0.5, s_valid=s,
                              window=window, bq=bq_eff, bk=bk_eff,
                              interpret=interpret)
    out = out[:, :s].reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return out
