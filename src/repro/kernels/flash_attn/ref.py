"""Pure-jnp oracle for the flash_attn kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, scale: float, window: int = 0):
    """q/k/v: (BH, S, hd); causal (optionally windowed) self-attention."""
    s = q.shape[1]
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = kp <= qp
    if window:
        mask &= kp > qp - window
    logits = jnp.where(mask[None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
