"""Pallas TPU kernel: causal flash attention (online-softmax, VMEM-resident).

Addresses the §Perf finding that the jnp chunked-attention path materializes
per-chunk score tensors in HBM (f32, score-shaped — the dominant memory term
of train cells): here scores/probabilities live entirely in VMEM scratch;
HBM sees only Q/K/V reads and the output write.

Grid: (B*H, S/bq, S/bk), KV innermost ("arbitrary").  Per (bh, i) the scratch
carries the online-softmax state (m, l, acc) across j blocks:

    s      = q_i k_j^T * scale        (bq x bk, MXU)
    m'     = max(m, rowmax(s))
    alpha  = exp(m - m')
    p      = exp(s - m')              (masked causally / beyond valid length)
    l      = alpha*l + rowsum(p)
    acc    = alpha*acc + p v_j
    out_i  = acc / l                  (flushed at the last j block)

Causal self-attention (S == T), optional sliding window.  GQA handled by the
ops.py wrapper (head expansion).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -1e30


def _make_kernel(bq: int, bk: int, scale: float, s_valid: int,
                 window: int):
    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        i = pl.program_id(1)
        j = pl.program_id(2)

        @pl.when(j == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qp = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (kp <= qp) & (kp < s_valid) & (qp < s_valid)
        if window:
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # fully-masked rows -> exp(NEG_INF-NEG_INF)=1
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

        @pl.when(j == pl.num_programs(2) - 1)
        def _flush():
            o_ref[0] = (acc_ref[...]
                        / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("scale", "window", "s_valid",
                                             "bq", "bk", "interpret"))
def flash_attention_raw(q, k, v, *, scale: float, s_valid: int,
                        window: int = 0, bq: int = 128, bk: int = 128,
                        interpret: bool = False):
    """q/k/v: (BH, S, hd) with S % bq == 0 == S % bk. Causal self-attention."""
    bh, s, hd = q.shape
    assert s % bq == 0 and s % bk == 0
    grid = (bh, s // bq, s // bk)
    return pl.pallas_call(
        _make_kernel(bq, bk, scale, s_valid, window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
