"""FleetEngine + FleetTrainLoop: the Engine/loop stack on every host.

:class:`FleetEngine` owns one :class:`repro.launch.engine.Engine` per host
the coordinator drives — each with its own sub-mesh and its own telemetry
:class:`Registry` — plus ONE fleet-level :class:`StragglerMonitor` fed with
real per-host step times (the single-controller stack only ever showed it
host 0).  :meth:`FleetEngine.merged_registry` is the controller's one fleet
telemetry view (exact histogram merge; see
:mod:`repro.fleet.telemetry_merge`).

:class:`FleetTrainLoop` composes the existing pieces instead of re-inventing
them:

  * the inner loop IS :class:`repro.runtime.fault_tolerance.FaultTolerantLoop`
    — checkpoint cadence, resume-from-latest, telemetry — with its
    ``host_times_fn`` supplying the per-host wall times the fleet step just
    measured and ``on_straggler`` escalating newly flagged hosts;
  * the escalation path is :func:`repro.runtime.elastic.shrink_after_failure`
    — the flagged host's devices leave the plan (whole-host units, per-replica
    batch preserved), the monitor forgets the host
    (:meth:`StragglerMonitor.replace_host`), and the supervisor re-enters
    ``FaultTolerantLoop.run``, which resumes from the latest committed
    checkpoint.  Surviving hosts keep their compiled-step caches, so the
    resumed steps replay with zero new traces.

Each virtual host steps its own state replica on its own sub-mesh (states
never cross meshes — committed arrays from one host's devices would clash
with another host's computation).  Checkpoints store the controller's
replica as host arrays, so any surviving host can re-fan-out from a restore.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.fleet.coordinator import Coordinator, LocalCoordinator
from repro.fleet.telemetry_merge import merge_registries, tagged_snapshot
from repro.launch.engine import Engine
from repro.runtime.elastic import MeshPlan, shrink_after_failure
from repro.runtime.fault_tolerance import FaultTolerantLoop
from repro.runtime.straggler import StragglerConfig, StragglerMonitor
from repro.telemetry import Registry, clock

__all__ = ["FleetEngine", "FleetTrainLoop", "HostStragglerError"]


class HostStragglerError(RuntimeError):
    """Raised out of the inner loop when the monitor flags hosts; carries
    the host indices so the supervisor can shrink around them."""

    def __init__(self, hosts: List[int]):
        super().__init__(f"straggling hosts flagged for removal: {hosts}")
        self.hosts = list(hosts)


class FleetEngine:
    """One Engine per driven host, one fleet monitor, one merged telemetry
    view.

    ``noise_seed`` is shared across hosts on purpose: in the replicated
    control-plane model every host folds the same key stream, so per-host
    outputs stay bit-identical (the fleet-vs-single-host oracle tests rely
    on it, and it matches real SPMD where the key is a broadcast scalar).
    """

    def __init__(self, coordinator: Coordinator, *, noise_seed: int = 0,
                 straggler_cfg: Optional[StragglerConfig] = None):
        self.coordinator = coordinator
        self.monitor = StragglerMonitor(
            cfg=straggler_cfg or StragglerConfig())
        self.engines: Dict[int, Engine] = {
            h.index: Engine(mesh=h.mesh, noise_seed=noise_seed,
                            registry=Registry())
            for h in coordinator.hosts()}
        self._hosts = {h.index: h for h in coordinator.hosts()}
        self._active = sorted(self.engines)
        self.removed: List[int] = []

    # ------------------------------------------------------------ topology
    def active_hosts(self) -> List[int]:
        return list(self._active)

    @property
    def controller(self) -> int:
        """The controller host (host 0, or its successor after a shrink)."""
        c = self.coordinator.controller
        return c if c in self._active else self._active[0]

    def host(self, index: int):
        return self._hosts[index]

    def engine(self, index: int) -> Engine:
        return self.engines[index]

    def remove_host(self, index: int) -> None:
        """Shrink path: the host leaves the fleet (its Engine is retired,
        its monitor entry + EWMA gauge are dropped).  Its Registry is kept —
        history already recorded still merges into the fleet view."""
        self._active.remove(index)
        self.removed.append(index)
        self.monitor.replace_host(index)
        if isinstance(self.coordinator, LocalCoordinator):
            self.coordinator.drop_host(index)

    # ----------------------------------------------------------- telemetry
    def observe_step_times(self, times: Dict[int, float]) -> List[int]:
        """Feed ONE step's per-host wall times; returns newly flagged hosts.

        Call once per fleet step with the full dict — feeding hosts one at a
        time would multiply the monitor's strike cadence by the fleet size.
        """
        return self.monitor.record_step(times)

    def snapshots(self) -> Dict[int, Dict]:
        """Per-host tagged snapshots (driven hosts only; gather for all)."""
        return {h: tagged_snapshot(self.engines[h].registry, h)
                for h in sorted(self.engines)}

    def merged_registry(self) -> Registry:
        """The fleet telemetry view (exact merge across per-host feeds)."""
        return merge_registries(
            {h: e.registry for h, e in self.engines.items()},
            self.coordinator)

    # --------------------------------------------------------------- stats
    def total_traces(self) -> int:
        return sum(e.stats.traces for e in self.engines.values())

    def traces_by_host(self) -> Dict[int, int]:
        return {h: e.stats.traces for h, e in self.engines.items()}


@dataclass
class FleetTrainLoop:
    """Run the fault-tolerant train loop on every host of a fleet.

    ``make_step(engine, host) -> (state, batch, step) -> state`` builds the
    per-host step callable once (under no mesh context; the loop activates
    the host's mesh around every call).  ``delay(host, step) -> extra_s``
    injects synthetic per-host skew into the *observed* times — chaos drills
    flag a straggler without sleeping through real seconds.
    """

    fleet: FleetEngine
    ckpt_root: str
    make_step: Callable[[Engine, int], Callable[[Any, Any, int], Any]]
    batch_fn: Callable[[int], Any]
    plan: MeshPlan
    model_parallel: int = 2
    ckpt_every: int = 2
    keep_last: int = 3
    delay: Optional[Callable[[int, int], float]] = None
    on_step: Optional[Callable[[int, Dict[int, float]], None]] = None
    shrinks: List[MeshPlan] = field(default_factory=list)

    def __post_init__(self):
        self._steps = {h: self.make_step(self.fleet.engine(h), h)
                       for h in self.fleet.active_hosts()}
        self._replicas: Dict[int, Any] = {}
        self._last_times: Dict[int, float] = {}

    # ------------------------------------------------------------ plumbing
    def _fan_out(self, state):
        """Host (uncommitted) copy of a state tree: placeable on any host's
        sub-mesh without cross-mesh device clashes."""
        return jax.tree.map(lambda x: jax.device_get(x), state)

    def _fleet_step(self, state, batch, step):
        host_state = None
        times: Dict[int, float] = {}
        for h in self.fleet.active_hosts():
            rep = self._replicas.get(h)
            if rep is None:
                if host_state is None:
                    host_state = self._fan_out(state)
                rep = host_state
            eng = self.fleet.engine(h)
            t0 = clock()
            with eng.activate():
                rep = self._steps[h](rep, batch, step)
            dt = clock() - t0
            if self.delay is not None:
                dt += self.delay(h, step)
            times[h] = dt
            self._replicas[h] = rep
        self._last_times = times
        if self.on_step:
            self.on_step(step, times)
        return self._replicas[self.fleet.controller]

    def _handle_stragglers(self, hosts: List[int]):
        lost = sum(self.fleet.host(h).n_devices for h in hosts)
        self.plan = shrink_after_failure(self.plan, lost,
                                         model_parallel=self.model_parallel)
        self.shrinks.append(self.plan)
        for h in hosts:
            self.fleet.remove_host(h)
            self._steps.pop(h, None)
        # every replica re-fans-out from the restored checkpoint: survivors
        # replay the post-checkpoint steps bit-identically to a fleet that
        # never contained the straggler
        self._replicas.clear()

    # ----------------------------------------------------------------- run
    def run(self, init_state, n_steps: int):
        """Train to ``n_steps``; flagged hosts shrink the plan and the loop
        resumes from the latest committed checkpoint.  Returns the
        controller replica's final state."""

        def escalate(flagged):
            raise HostStragglerError(flagged)

        while True:
            loop = FaultTolerantLoop(
                self.ckpt_root, self._fleet_step, self.batch_fn,
                ckpt_every=self.ckpt_every, keep_last=self.keep_last,
                monitor=self.fleet.monitor,
                host_times_fn=lambda dt: dict(self._last_times) or {0: dt},
                on_straggler=escalate)
            try:
                return loop.run(init_state, n_steps)
            except HostStragglerError as e:
                if len(self.fleet.active_hosts()) <= len(e.hosts):
                    raise  # nothing left to shrink onto
                self._handle_stragglers(e.hosts)
                self.fleet.coordinator.barrier("fleet.shrink")
