"""FleetServer: the continuous-batching Server on every host of a fleet.

One :class:`repro.launch.server.Server` per host, each on its own sub-mesh
Engine with its own telemetry Registry; requests are routed round-robin at
submit and every host decodes its own lockstep batch.  Per-slot decode is
independent and batched-vs-sequential bit-identity is already pinned
(tests/test_paged_kv.py), so fleet-served token streams are bit-identical to
a single-host Server fed the same requests — the oracle the fleet tests
assert against.

Per-tick host wall times feed the fleet :class:`StragglerMonitor` with real
per-host entries (only hosts that actually decoded a tick report — idle
hosts must not drag the fleet median toward zero), and
:meth:`FleetServer.slos` reads the SLO trio off the MERGED registry view, so
fleet TTFT/TPOT percentiles are exact as-if-one-registry numbers.

Params are fanned out as host (uncommitted) arrays once at construction:
committed arrays from one sub-mesh cannot feed another sub-mesh's
computation, and uncommitted leaves place freely on every host.
"""
from __future__ import annotations

from typing import Dict, List

import jax

from repro.fleet.fleet_engine import FleetEngine
from repro.launch.server import Handle, Request, Server
from repro.telemetry import clock, serving_slos

__all__ = ["FleetServer"]


class FleetServer:
    """Route -> per-host Server -> merged telemetry.  Same submit/poll/drain
    surface as :class:`repro.launch.server.Server`, fleet-wide."""

    def __init__(self, cfg, params, fleet: FleetEngine, **server_kw):
        self.fleet = fleet
        self.attn_impl = None
        host_params = jax.tree.map(lambda x: jax.device_get(x), params)
        self.servers: Dict[int, Server] = {}
        for h in fleet.active_hosts():
            eng = fleet.engine(h)
            with eng.activate():
                srv = Server(cfg, host_params, engine=eng, host=h,
                             **server_kw)
            self.servers[h] = srv
            self.attn_impl = srv.attn_impl
        self._order = list(self.servers)
        self._rr = 0
        self.handles: List[Handle] = []

    @property
    def n_hosts(self) -> int:
        return len(self.servers)

    # ----------------------------------------------------------- public API
    def submit(self, request: Request) -> Handle:
        """Round-robin a request onto the next host's admission queue."""
        h = self._order[self._rr % len(self._order)]
        self._rr += 1
        with self.fleet.engine(h).activate():
            handle = self.servers[h].submit(request)
        handle.host = h  # fleet-side tag (per-host Handles count rids alone)
        self.handles.append(handle)
        return handle

    def poll(self) -> List[Handle]:
        """One fleet tick: every host admits + decodes one lockstep step.

        Hosts that decoded this tick feed their wall time to the fleet
        straggler monitor as one ``record_step`` call with real per-host
        entries."""
        finished: List[Handle] = []
        times: Dict[int, float] = {}
        for h, srv in self.servers.items():
            ticks0 = srv.decode_ticks
            t0 = clock()
            with self.fleet.engine(h).activate():
                finished.extend(srv.poll())
            if srv.decode_ticks > ticks0:  # it really ran a decode step
                times[h] = clock() - t0
        if times:
            self.fleet.observe_step_times(times)
        return finished

    def drain(self) -> List[Handle]:
        """Serve everything everywhere; returns handles in submit order."""
        while any(srv.queued or any(srv.active)
                  for srv in self.servers.values()):
            self.poll()
        return list(self.handles)

    # ------------------------------------------------------------ telemetry
    def slos(self) -> Dict:
        """Fleet SLO trio off the merged (exact) registry view."""
        return serving_slos(self.fleet.merged_registry(),
                            attn_impl=self.attn_impl, n_hosts=self.n_hosts)

    def total_decode_s(self) -> float:
        return sum(srv.decode_s for srv in self.servers.values())
