"""Fleet subsystem: many Engines on many hosts under one controller.

  coordinator     — who am I / rendezvous: ``DistributedCoordinator``
                    (jax.distributed) and ``LocalCoordinator`` (in-process
                    virtual fleet over device sub-meshes, CI-testable).
  fleet_engine    — per-host Engines + fleet StragglerMonitor +
                    ``FleetTrainLoop`` (straggler shrink + checkpoint-resume
                    over the existing FaultTolerantLoop).
  fleet_server    — per-host Servers, round-robin routing, merged SLOs.
  telemetry_merge — tagged per-host Registry snapshots -> one exact fleet
                    view (``Registry.merge``).
"""
from repro.fleet.coordinator import (Coordinator, DistributedCoordinator,
                                     FleetHost, LocalCoordinator)
from repro.fleet.fleet_engine import (FleetEngine, FleetTrainLoop,
                                      HostStragglerError)
from repro.fleet.fleet_server import FleetServer
from repro.fleet.telemetry_merge import (fleet_slos, merge_registries,
                                         merge_tagged, tagged_snapshot)

__all__ = [
    "Coordinator", "DistributedCoordinator", "FleetHost", "LocalCoordinator",
    "FleetEngine", "FleetTrainLoop", "HostStragglerError", "FleetServer",
    "fleet_slos", "merge_registries", "merge_tagged", "tagged_snapshot",
]
