"""Fleet telemetry: per-host Registry snapshots -> one merged fleet view.

Each host records into its OWN :class:`repro.telemetry.Registry` (recording
stays host-side and lock-free across the fleet); the controller periodically
pulls snapshots — tagged with the producing process index — through the
coordinator's ``all_gather`` and merges them with
:meth:`repro.telemetry.Registry.merge`:

  * counters sum, gauge values sum, gauge high-waters take the max,
  * histogram **bucket counts add exactly** (snapshots carry their sparse
    bucket state), so fleet p50/p95/p99 are *as-if-one-registry* — not an
    average of per-host percentiles, which is a different (and wrong)
    statistic.

``serving_slos(merged_registry, n_hosts=...)`` and
``benchmarks/run.py --compare`` consume the merged view; the raw tagged
snapshots stay available for per-host drill-down (the straggler gauges
``straggler.ewma_s.host*`` are already per-host named, so they survive the
merge unaliased).
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple

from repro.telemetry import Registry, snapshot

__all__ = ["tagged_snapshot", "merge_tagged", "merge_registries",
           "fleet_slos"]


def tagged_snapshot(registry: Registry, process_index: int) -> Dict:
    """One host's snapshot, stamped with who produced it."""
    snap = snapshot(registry)
    snap["process_index"] = process_index
    return snap


def merge_tagged(snaps: Iterable[Dict]) -> Tuple[Registry, Dict[int, Dict]]:
    """Merge tagged snapshots -> (merged Registry, {process_index: snap}).

    Order-insensitive: snapshots are merged in process-index order so the
    controller's merged view is deterministic regardless of gather order.
    Untagged snapshots (legacy single-host callers) merge under index -1.
    """
    by_host = {s.get("process_index", -1): s for s in snaps}
    ordered = [by_host[i] for i in sorted(by_host)]
    merged = Registry.merge(*[
        {k: v for k, v in s.items() if k != "process_index"}
        for s in ordered])
    return merged, by_host


def merge_registries(per_host: Dict[int, Registry],
                     coordinator=None) -> Registry:
    """Snapshot + tag every host registry, gather, and merge.

    ``coordinator=None`` merges locally (virtual fleet / tests); with a
    coordinator the tagged snapshots travel through ``all_gather`` so every
    process — controller included — ends up with the same fleet view.
    """
    tagged = {h: tagged_snapshot(reg, h) for h, reg in per_host.items()}
    if coordinator is not None:
        tagged = coordinator.all_gather(tagged)
    merged, _ = merge_tagged(tagged.values())
    return merged


def fleet_slos(per_host: Dict[int, Registry], *, attn_impl: Optional[str]
               = None, coordinator=None) -> Dict:
    """Serving SLOs over the merged fleet view, tagged with ``n_hosts``."""
    from repro.telemetry import serving_slos

    merged = merge_registries(per_host, coordinator)
    return serving_slos(merged, attn_impl=attn_impl,
                        n_hosts=len(per_host))
