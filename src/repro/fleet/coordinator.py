"""Coordinator: who am I in the fleet, and how do hosts rendezvous.

The paper's 8x8 macro is one tile; one Engine on one host is the serving
analogue.  Fleet scale means many identical Engines under one controller —
this module is that controller's substrate.  Two implementations of one
small :class:`Coordinator` protocol:

  * :class:`DistributedCoordinator` — a thin wrapper over
    ``jax.distributed.initialize`` for REAL multi-process fleets: process
    index/count, a barrier (``sync_global_devices``), a host-0 controller
    election, and an object all-gather (JSON over a padded uint8
    ``process_allgather``) used to ship per-host telemetry snapshots to the
    controller.  Each process drives exactly one :class:`FleetHost` whose
    mesh spans the *global* device set (normal SPMD).
  * :class:`LocalCoordinator` — an in-process **virtual fleet**: the local
    devices are partitioned into ``n_hosts`` contiguous groups, each with
    its own (data, model) sub-mesh.  One Python process drives every
    virtual host, so the multi-host control flow — per-host step times into
    the straggler monitor, telemetry merge on the controller, shrink/resume
    after a flagged host — is exercisable in CI on CPU
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) without
    spawning processes.

Both sides agree on the contract the fleet engine/server layers consume:
``hosts()`` (the hosts THIS process drives), ``process_count``,
``controller`` / ``is_controller``, ``barrier(tag)``, and
``all_gather(per_host)`` returning the full fleet view on every caller.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from repro.launch.mesh import make_submesh, partition_devices


@dataclass(frozen=True)
class FleetHost:
    """One host's identity: its fleet-wide index and its mesh/devices."""

    index: int
    devices: Tuple[Any, ...]
    mesh: Any = field(hash=False, compare=False)

    @property
    def n_devices(self) -> int:
        return len(self.devices)


class Coordinator:
    """Protocol (duck-typed; both implementations subclass for isinstance
    convenience, but the fleet layers only rely on the methods below)."""

    def hosts(self) -> List[FleetHost]:
        """The hosts this process drives (1 for distributed, N for local)."""
        raise NotImplementedError

    @property
    def process_count(self) -> int:
        raise NotImplementedError

    @property
    def controller(self) -> int:
        """Host index elected controller (host 0 by convention)."""
        return 0

    def is_controller(self) -> bool:
        """Does this process drive the controller host?"""
        return any(h.index == self.controller for h in self.hosts())

    def barrier(self, tag: str) -> None:
        raise NotImplementedError

    def all_gather(self, per_host: Dict[int, Any]) -> Dict[int, Any]:
        """Combine each process's {host_index: obj} into the fleet view."""
        raise NotImplementedError


class LocalCoordinator(Coordinator):
    """In-process virtual fleet: N sub-meshes over the local devices.

    ``LocalCoordinator(2)`` with 8 forced CPU devices yields two virtual
    hosts of 4 devices each, meshes ``(2, 2)`` over disjoint device groups.
    Every cross-host primitive is trivial (one process, synchronous), which
    is the point: the *control flow* above it — per-host Engines, merged
    registries, straggler shrink — is identical to the distributed path.
    """

    def __init__(self, n_hosts: int, *, devices: Optional[Sequence] = None,
                 model_parallel: int = 2):
        groups = partition_devices(n_hosts, devices)
        self._hosts = [
            FleetHost(i, devs, make_submesh(devs, model_parallel))
            for i, devs in enumerate(groups)]

    def hosts(self) -> List[FleetHost]:
        return list(self._hosts)

    @property
    def process_count(self) -> int:
        return 1

    def barrier(self, tag: str) -> None:  # one process: always in sync
        return None

    def all_gather(self, per_host: Dict[int, Any]) -> Dict[int, Any]:
        return dict(per_host)

    def drop_host(self, index: int) -> FleetHost:
        """Remove a virtual host from the fleet (straggler shrink)."""
        for i, h in enumerate(self._hosts):
            if h.index == index:
                return self._hosts.pop(i)
        raise KeyError(f"no virtual host {index}")


class DistributedCoordinator(Coordinator):
    """Thin wrapper over ``jax.distributed`` for real multi-process fleets.

    ``initialize=True`` calls ``jax.distributed.initialize`` (env-driven or
    with the explicit coordinator address); pass ``initialize=False`` when
    the runtime already did (or in single-process smoke runs, where every
    primitive degenerates to the local case and stays cheap).
    """

    def __init__(self, *, initialize: bool = False,
                 coordinator_address: Optional[str] = None,
                 num_processes: Optional[int] = None,
                 process_id: Optional[int] = None,
                 model_parallel: int = 2):
        if initialize:
            jax.distributed.initialize(
                coordinator_address=coordinator_address,
                num_processes=num_processes, process_id=process_id)
        self._index = jax.process_index()
        self._count = jax.process_count()
        # normal SPMD: every process runs the same program over the GLOBAL
        # mesh; the per-host identity is the process index.
        n = len(jax.devices())
        mp = model_parallel if n % model_parallel == 0 else 1
        mesh = jax.make_mesh((n // mp, mp), ("data", "model"))
        self._host = FleetHost(self._index, tuple(jax.local_devices()), mesh)

    def hosts(self) -> List[FleetHost]:
        return [self._host]

    @property
    def process_count(self) -> int:
        return self._count

    def barrier(self, tag: str) -> None:
        if self._count == 1:
            return
        from jax.experimental import multihost_utils

        multihost_utils.sync_global_devices(tag)

    def all_gather(self, per_host: Dict[int, Any]) -> Dict[int, Any]:
        """Gather one JSON-able object per process (telemetry snapshots)."""
        if self._count == 1:
            return dict(per_host)
        import numpy as np
        from jax.experimental import multihost_utils

        payload = json.dumps(per_host.get(self._index)).encode()
        # fixed-width lane: pad to the fleet max so allgather shapes agree
        n = np.asarray([len(payload)], np.int32)
        max_n = int(multihost_utils.process_allgather(n).max())
        buf = np.zeros((max_n,), np.uint8)
        buf[:len(payload)] = np.frombuffer(payload, np.uint8)
        lens = multihost_utils.process_allgather(n)[:, 0]
        bufs = multihost_utils.process_allgather(buf)
        return {i: json.loads(bytes(bufs[i, :int(lens[i])]).decode())
                for i in range(self._count)}
