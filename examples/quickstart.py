"""Quickstart: the paper's 8x8 8T SRAM IMC array, end to end.

Walks the full Fig-5 pipeline — operand load (8 write cycles), pre-charge,
multi-row evaluation, comparator decode — then derives every logic function
of Table II from single MAC evaluations, and finishes with the production
entry point: ONE typed :class:`FabricSpec` per fabric configuration, driven
through the :class:`Fabric` facade (exact digital-equivalent, fused Pallas
sim, and PRNG-keyed noisy sim side by side).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ArraySpec, Fabric, FabricSpec, NoiseSpec, Timing,
                        empty_state, logic2, mac, mac_energy_fj, write_row)

spec = ArraySpec()  # 8x8, Table-I calibrated

# ---- 1. store operand B (one row per 7 ns write cycle, Fig 5) -------------
print("== MAC: A . B over 8 rows of one column ==")
rng = np.random.default_rng(0)
B_bits = rng.integers(0, 2, size=(8, 8)).astype(np.uint8)
state = empty_state(spec)
for r in range(8):
    state = write_row(state, r, B_bits[r])

# ---- 2. pre-charge + assert RWLs with operand A (0.7 ns window) -----------
A_bits = rng.integers(0, 2, size=8).astype(np.uint8)
res = mac(state, A_bits, spec)
expected = A_bits.astype(int) @ B_bits
for col in range(8):
    code = "".join(str(int(b)) for b in res.codes[col])
    print(f" col{col}: count={int(res.counts[col])} (true {expected[col]}) "
          f"V_RBL={float(res.volts[col]):.3f}V code={code} "
          f"E={float(res.energy_fj[col]):.1f}fJ")
assert np.array_equal(np.asarray(res.counts), expected)

t = Timing()
print(f" timing: op={t.t_op_s*1e9:.0f}ns (9 x 7ns cycles) "
      f"eval={t.t_eval_s*1e9:.1f}ns throughput={t.throughput_ops/1e6:.1f}Mops/s")

# ---- 3. MAC-derived logic (Table II): 8-bit bitwise ops, one evaluation ---
print("\n== MAC-derived logic: 8-bit bitwise ops from ONE evaluation ==")
wa = rng.integers(0, 2, size=8).astype(np.uint8)
wb = rng.integers(0, 2, size=8).astype(np.uint8)
state = write_row(write_row(empty_state(spec), 0, wa), 1, wb)
out, r2 = logic2(state, 0, 1, spec)
print(f" A     = {wa}\n B     = {wb}")
for op in ("AND", "NAND", "OR", "NOR", "XOR", "XNOR", "SUM", "CARRY"):
    print(f" {op:5s} = {np.asarray(out[op])}")
assert np.array_equal(np.asarray(out["AND"]), wa & wb)
assert np.array_equal(np.asarray(out["XOR"]), wa ^ wb)

# ---- 4. N-bit MAC through the Fabric facade: one spec per configuration ---
print("\n== FabricSpec: exact / fused-sim / noisy-sim, side by side ==")
x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
ref = x @ w

specs = [
    # digital equivalent: int8 GEMM (auto -> MXU Pallas kernel on TPU)
    FabricSpec(mode="exact"),
    # hardware-faithful sim, fully fused Pallas kernel (interpret on CPU)
    FabricSpec(mode="sim", backend="pallas"),
    # keyed analog non-idealities: device mismatch at the calibrated sigma
    FabricSpec(mode="sim", backend="jnp", noise=NoiseSpec.calibrated()),
    # reconfigurable precision: 4-bit activations x 8-bit weights
    FabricSpec(bits_a=4, bits_w=8, mode="sim", backend="jnp"),
]
key = jax.random.key(0)
for spec in specs:
    fab = Fabric(spec)
    y = fab.matmul(x, w, key=key if spec.noisy else None)
    rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    print(f" {spec.label:14s} ({spec.bits_a}x{spec.bits_w}b) rel err {rel:.4f}")

# the same spec prices the op on the modeled hardware...
rep = Fabric(specs[0]).cost(x.shape, w.shape)
print(f" cost[{specs[0].label}]: {rep.evaluations} evaluations, "
      f"E={rep.energy_j*1e12:.2f}pJ, {rep.tops_per_w:.2f} TOPS/W-1b")
# ...and drives the MAC-derived logic of section 3 (exact == analog decode)
xor = Fabric(FabricSpec(mode="sim")).logic(wa, wb, "XOR")
assert np.array_equal(np.asarray(xor), wa ^ wb)
print(f" fabric logic XOR through the analog decode: {np.asarray(xor)}")
# word level: packed uint8 operands, 8 columns per MAC activation (§III)
pa, pb = np.uint8(0xC5), np.uint8(0x3A)
fab_sim = Fabric(FabricSpec(mode="sim"))
nand = fab_sim.logic_word(pa, pb, "NAND")
tot, carry = fab_sim.add_nbit(pa, pb)
assert int(nand) == (~(pa & pb)) & 0xFF
assert int(tot) == (int(pa) + int(pb)) & 0xFF
print(f" word logic: 0x{pa:02X} NAND 0x{pb:02X} = 0x{int(nand):02X}; "
      f"ripple-carry add -> 0x{int(tot):02X} carry {int(carry)}")
print(f" energy model: count=8 eval costs {float(mac_energy_fj(8)):.1f} fJ "
      f"(paper Table III: 452.2 fJ)")
print("\nquickstart OK")
