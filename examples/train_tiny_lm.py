"""End-to-end driver: train the ~110M-parameter paper-demonstrator LM for a
few hundred steps with EVERY projection running through the IMC fabric's
exact digital-equivalent path (int8 bit-plane MAC), fault-tolerant loop +
checkpointing included.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
(--small trains a width-reduced variant in seconds; default is the full 110M.)
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="width-reduced variant (CI-speed)")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config("imc-paper-110m")
    if args.small:
        cfg = reduce_config(cfg)
    batch = args.batch or (8 if args.small else 4)
    seq = args.seq or (64 if args.small else 512)

    with tempfile.TemporaryDirectory() as ckpt:
        (params, _), hist = train(cfg, steps=args.steps, global_batch=batch,
                                  seq_len=seq, ckpt_root=ckpt,
                                  ckpt_every=max(args.steps // 4, 1),
                                  lr=1e-3)
    losses = [m["loss"] for m in hist]
    n = sum(np.asarray(x).size for x in jax.tree.leaves(params))
    fab = cfg.imc_fabric
    print(f"params: {n/1e6:.1f}M  (fabric={fab.label}, "
          f"{fab.bits_a}x{fab.bits_w}-bit)" if fab else
          f"params: {n/1e6:.1f}M  (fabric off)")
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"over {args.steps} steps")
    assert losses[-1] < losses[0], "training must reduce loss"
    print("train_tiny_lm OK")


if __name__ == "__main__":
    main()
