"""Batched serving example: continuous-batching decode over a request queue
(prefill -> slot merge -> lockstep decode -> retire), on a reduced qwen2.5
config so it runs on CPU in seconds.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch gemma3-12b]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.launch.mesh import dp_axes, make_test_mesh, tp_axis
from repro.launch.serve import BatchedServer, Request
from repro.models.common import AxisCtx, axis_ctx
from repro.models.model import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=10)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    rng = np.random.default_rng(0)
    params = init_params(jax.random.key(0), cfg)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=24).astype(np.int32),
                    args.max_new) for i in range(args.requests)]

    mesh = make_test_mesh()
    with jax.set_mesh(mesh), axis_ctx(AxisCtx(dp_axes(mesh), tp_axis(mesh))):
        server = BatchedServer(cfg, params, slots=args.slots, prompt_len=24,
                               max_new=args.max_new)
        done, tps = server.run(reqs)

    assert all(len(r.out) == args.max_new for r in done)
    for r in done:
        print(f"req{r.rid}: generated {r.out}")
    print(f"{args.requests} requests through {args.slots} slots; "
          f"{tps:.1f} tok/s lockstep decode")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
