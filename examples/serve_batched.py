"""Batched serving example on the typed Server API (submit / poll / drain):
ragged prompts are right-padded to per-bucket prefill executables, KV lives in
a paged block pool with per-slot block tables, and decode runs all slots in
lockstep through ONE compiled step.  Runs a reduced qwen2.5 config so it
finishes on CPU in seconds.

The example serves ``--waves`` identical waves of mixed-length requests and
asserts that every wave after the first is trace-free: the compiled-step
cache plus block-table-as-data design means steady-state traffic never
recompiles, which ``Engine.stats.traces`` pins down.

Run:  PYTHONPATH=src python examples/serve_batched.py [--lengths 7,16,33]
Add ``--imc-mode sim --imc-noise-sigma 0.05`` for a noisy fabric, or
``--kv ring`` for the legacy fixed-ring geometry (uniform lengths only).
``--trace-out trace.json`` exports the run's prefill/decode spans as Chrome
trace-event JSON — drop the file into https://ui.perfetto.dev to see the
serving timeline; ``--telemetry`` prints the metric snapshot as markdown.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.fabric import add_fabric_cli, apply_fabric_cli
from repro.launch.engine import Engine
from repro.launch.server import Request, Server
from repro.models.model import init_params
from repro.runtime.straggler import StragglerMonitor
from repro.telemetry import (export_chrome_trace, serving_slos, to_markdown)


def serve_fleet(cfg, params, rng, lengths, buckets, args):
    """The same waves through an N-host virtual fleet: round-robin routing,
    per-host Engines, and SLOs off the merged (exact) fleet registry.
    Steady-state trace-freeness holds per host, so the FLEET trace total is
    flat across waves 2+ too."""
    from repro.fleet import FleetEngine, FleetServer, LocalCoordinator

    fleet = FleetEngine(LocalCoordinator(args.fleet_hosts),
                        noise_seed=args.seed)
    server = FleetServer(cfg, params, fleet, slots=args.slots, kv=args.kv,
                         block_size=args.block_size, buckets=buckets,
                         attn_impl=args.attn_impl,
                         max_seq_len=max(buckets) + args.max_new)
    warm_traces = None
    total_tokens, t0 = 0, time.perf_counter()
    for wave in range(args.waves):
        handles = [server.submit(Request(
            prompt=rng.integers(0, cfg.vocab_size, size=n).astype(np.int32),
            max_new_tokens=args.max_new)) for n in lengths]
        server.drain()
        assert all(h.done for h in handles), \
            [(h.status, h.reason) for h in handles]
        total_tokens += sum(len(h.tokens) for h in handles)
        # round-robin rotates which host sees which bucket, so warmup takes
        # n_hosts waves; every later wave must be trace-free fleet-wide
        if wave == args.fleet_hosts - 1:
            warm_traces = fleet.total_traces()
        elif wave >= args.fleet_hosts:
            assert fleet.total_traces() == warm_traces, (
                f"steady-state recompile: fleet traces went {warm_traces} "
                f"-> {fleet.total_traces()} on wave {wave}")
    dt = time.perf_counter() - t0
    for h in server.handles:
        print(f"req{h.rid}@host{h.host} (len={len(h.request.prompt)}): "
              f"generated {h.tokens}")
    slos = server.slos()
    print(f"{len(server.handles)} requests over {server.n_hosts} virtual "
          f"hosts; {total_tokens / dt:.1f} tok/s end-to-end; "
          f"fleet traces {fleet.total_traces()} "
          f"(per host {fleet.traces_by_host()}), waves 2+ trace-free")
    print(f"merged SLOs (n_hosts={slos['n_hosts']}): ttft p50 "
          f"{slos['ttft_ms']} ms, tpot p50 {slos['tpot_ms']} ms, peak "
          f"block occupancy {slos['occupancy_peak']}")
    if args.telemetry:
        print(to_markdown(registry=fleet.merged_registry()))
    print("serve_batched OK (fleet)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--lengths", default="7,16,33",
                    help="comma-separated ragged prompt lengths; one request "
                         "per length per wave")
    ap.add_argument("--waves", type=int, default=2,
                    help="identical request waves; waves after the first "
                         "must be trace-free")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--kv", default="paged", choices=["paged", "ring"])
    ap.add_argument("--attn-impl", default=None, choices=["jnp", "pallas"],
                    help="paged-decode attention engine (default: pallas on "
                         "TPU, jnp elsewhere; pallas runs interpreted on CPU)")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write prefill/decode spans as Chrome trace-event "
                         "JSON (loadable in Perfetto / chrome://tracing)")
    ap.add_argument("--telemetry", action="store_true",
                    help="print the telemetry snapshot as markdown tables")
    ap.add_argument("--fleet-hosts", type=int, default=1,
                    help="virtual fleet: partition local devices into N "
                         "hosts (device count must divide), route requests "
                         "round-robin, and report merged-registry SLOs")
    add_fabric_cli(ap)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    cfg = apply_fabric_cli(ap, args, cfg, jitted_what="server")
    lengths = [int(x) for x in args.lengths.split(",")]
    if args.kv == "ring":  # legacy geometry serves ONE uniform shape
        lengths = [lengths[0]] * len(lengths)
    buckets = sorted({-(-n // 16) * 16 for n in lengths})
    rng = np.random.default_rng(0)
    params = init_params(jax.random.key(0), cfg)

    if args.fleet_hosts > 1:
        serve_fleet(cfg, params, rng, lengths, buckets, args)
        return

    engine = Engine(noise_seed=args.seed, monitor=StragglerMonitor())
    with engine.activate():
        server = Server(cfg, params, engine=engine, slots=args.slots,
                        kv=args.kv, block_size=args.block_size,
                        buckets=buckets, attn_impl=args.attn_impl,
                        max_seq_len=max(buckets) + args.max_new)
        warm_traces = None
        total_tokens, t0 = 0, time.perf_counter()
        for wave in range(args.waves):
            handles = [server.submit(Request(
                prompt=rng.integers(0, cfg.vocab_size, size=n)
                          .astype(np.int32),
                max_new_tokens=args.max_new)) for n in lengths]
            server.drain()
            assert all(h.done for h in handles), \
                [(h.status, h.reason) for h in handles]
            assert all(len(h.tokens) == args.max_new for h in handles)
            total_tokens += sum(len(h.tokens) for h in handles)
            if wave == 0:
                warm_traces = engine.stats.traces
            else:  # steady state: same length mix -> zero new traces
                assert engine.stats.traces == warm_traces, (
                    f"steady-state recompile: traces went {warm_traces} -> "
                    f"{engine.stats.traces} on wave {wave}")
    dt = time.perf_counter() - t0

    for h in server.handles:
        print(f"req{h.rid} (len={len(h.request.prompt)}): "
              f"generated {h.tokens}")
    print(f"{len(server.handles)} requests ({args.waves} waves, lengths "
          f"{lengths}) through {args.slots} slots "
          f"[{args.kv}, attn={server.attn_impl}]; "
          f"{total_tokens / dt:.1f} tok/s end-to-end; "
          f"{engine.stats.compiles} compiled steps, {engine.stats.traces} "
          f"traces, waves 2+ trace-free")
    slos = serving_slos(engine.registry, attn_impl=server.attn_impl)
    print(f"SLOs: ttft p50 {slos['ttft_ms']} ms, tpot p50 {slos['tpot_ms']} "
          f"ms, peak block occupancy {slos['occupancy_peak']}")
    if args.telemetry:
        print(to_markdown(registry=engine.registry))
    if args.trace_out:
        print(f"chrome trace -> {export_chrome_trace(args.trace_out)} "
              f"(open in https://ui.perfetto.dev)")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
