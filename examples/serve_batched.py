"""Batched serving example: continuous-batching decode over a request queue
(prefill -> slot merge -> lockstep decode -> retire), on a reduced qwen2.5
config so it runs on CPU in seconds.  The Engine owns mesh, step compilation
(one executable per kind — no recompiles at steady state), and the noise
keys, so add ``--imc-mode sim --imc-noise-sigma 0.05`` for a noisy fabric.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch gemma3-12b]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.fabric import add_fabric_cli, apply_fabric_cli
from repro.launch.engine import Engine
from repro.launch.serve import BatchedServer, Request
from repro.models.model import init_params
from repro.runtime.straggler import StragglerMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    add_fabric_cli(ap)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    cfg = apply_fabric_cli(ap, args, cfg, jitted_what="server")
    rng = np.random.default_rng(0)
    params = init_params(jax.random.key(0), cfg)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=24).astype(np.int32),
                    args.max_new) for i in range(args.requests)]

    engine = Engine(noise_seed=args.seed, monitor=StragglerMonitor())
    with engine.activate():
        server = BatchedServer(cfg, params, slots=args.slots, prompt_len=24,
                               max_new=args.max_new, engine=engine)
        done, tps = server.run(reqs)

    assert all(len(r.out) == args.max_new for r in done)
    for r in done:
        print(f"req{r.rid}: generated {r.out}")
    print(f"{args.requests} requests through {args.slots} slots; "
          f"{tps:.1f} tok/s lockstep decode; {engine.stats.compiles} compiled "
          f"steps, {engine.stats.traces} traces (steady state recompile-free)")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
