"""Decode-attention microbench: paged gather (jnp) vs fused kernel (pallas).

Context-length sweep over the op the serving hot loop spends its decode time
in — :func:`repro.kernels.paged_attn.ops.paged_attention` against shared
paged pools through ragged block tables.  One row per (attn_impl, T_ctx);
each row's ``derived`` column carries decode tokens/s for the batch plus the
impl tag, so the perf trajectory never conflates the two engines.  On CPU the
pallas rows run through the Pallas interpreter (flagged ``interpret=True`` in
the row, exempt from the jnp-vs-kernel throughput comparison — Mosaic only
compiles on TPU).

Geometry mirrors serving: per-slot positions are staggered (3/4, full, 1/4,
1/2 of T_ctx) so tables are ragged with ``-1`` sentinel tails and partially
filled last blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.kernels.compat import default_interpret
from repro.kernels.paged_attn.ops import paged_attention


def _case(rng, ctx: int, *, b=4, h=8, kv=2, hd=64, bs=16):
    mb = ctx // bs
    pos = np.array([ctx * 3 // 4, ctx - 1, ctx // 4, ctx // 2][:b]) \
        .astype(np.int32)
    nb = int(sum(p // bs + 1 for p in pos)) + 1
    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)), jnp.bfloat16)
    kp = jnp.asarray(rng.standard_normal((nb, bs, kv, hd)), jnp.bfloat16)
    vp = jnp.asarray(rng.standard_normal((nb, bs, kv, hd)), jnp.bfloat16)
    tbl = np.full((b, mb), -1, np.int32)
    perm = iter(rng.permutation(nb))
    for i, p in enumerate(pos):
        for j in range(p // bs + 1):
            tbl[i, j] = next(perm)
    return q, kp, vp, jnp.asarray(tbl), jnp.asarray(pos)


def paged_decode_attention(smoke: bool = False):
    rows = []
    ctxs = (256, 1024) if smoke else (512, 2048, 8192)
    iters = 5 if smoke else 20
    interp = default_interpret()
    rng = np.random.default_rng(0)
    for ctx in ctxs:
        q, kp, vp, tbl, pos = _case(rng, ctx)
        b = q.shape[0]
        for impl in ("jnp", "pallas"):
            fn = jax.jit(lambda q, kp, vp, tbl, pos, impl=impl:
                         paged_attention(q, kp, vp, tbl, pos, impl=impl))
            n_it = iters if (impl == "jnp" or not interp) else min(iters, 3)
            us, _ = time_fn(fn, q, kp, vp, tbl, pos, iters=n_it)
            tag = (" interpret=True (oracle-mode; not perf)"
                   if impl == "pallas" and interp else "")
            rows.append(row(
                f"paged_decode_attn/{impl}/ctx{ctx}", us,
                f"attn_impl={impl} {b / (us * 1e-6):.0f}tok/s{tag}"))
    return rows


ALL = [paged_decode_attention]
