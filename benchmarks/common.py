"""Benchmark harness utilities: timing + CSV rows."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, iters: int = 20, warmup: int = 3, **kw):
    """Median wall time per call in microseconds (values blocked)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6, out


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
