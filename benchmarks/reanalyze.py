"""Recompute roofline fields of dry-run JSONs from their stored .hlo.gz
modules (no recompilation needed when the HLO cost model improves).

Usage: PYTHONPATH=src python -m benchmarks.reanalyze [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import gzip
import json
import os

from repro.launch.hlo_analysis import roofline_terms
from repro.launch.hlo_costs import analyze


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "experiments", "dryrun"))
    args = ap.parse_args()
    for name in sorted(os.listdir(args.dir)):
        if not name.endswith(".json"):
            continue
        stem = name[:-5]
        hlo_path = os.path.join(args.dir, stem + ".hlo.gz")
        if not os.path.exists(hlo_path):
            print(f"SKIP {stem} (no stored HLO)")
            continue
        with gzip.open(hlo_path, "rt") as f:
            costs = analyze(f.read())
        jpath = os.path.join(args.dir, name)
        rec = json.load(open(jpath))
        mf = rec["roofline"]["model_flops_per_device"] * rec["n_devices"]
        rl = roofline_terms(
            {"flops": costs.flops, "bytes accessed": costs.hbm_bytes,
             "flops_int8": costs.flops_int8},
            dict(costs.coll_by_type), model_flops_total=mf,
            n_devices=rec["n_devices"])
        rec["roofline"] = rl.as_dict()
        json.dump(rec, open(jpath, "w"), indent=1)
        print(f"REDO {stem}: dom={rl.dominant} "
              f"t=({rl.t_compute_s:.2e},{rl.t_memory_s:.2e},"
              f"{rl.t_collective_s:.2e})")


if __name__ == "__main__":
    main()
