"""Roofline report: aggregate dry-run JSONs into the EXPERIMENTS.md table.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
Prints a markdown table of compute/memory/collective terms per cell and the
dominant bottleneck; also emits CSV rows for benchmarks.run.
"""
from __future__ import annotations

import argparse
import json
import os

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")


def load(dir_=DEFAULT_DIR):
    recs = []
    if not os.path.isdir(dir_):
        return recs
    for name in sorted(os.listdir(dir_)):
        if name.endswith(".json"):
            with open(os.path.join(dir_, name)) as f:
                recs.append(json.load(f))
    return recs


def fraction_of_roofline(rec):
    """max(term)/sum-ish quality: useful-FLOPs time over the bound.

    We report: bound = max(t_compute, t_memory, t_collective); the 'roofline
    fraction' = t_model_compute / bound, where t_model_compute uses the
    analytic 6*N*D model FLOPs (what a perfect implementation would need).
    """
    r = rec["roofline"]
    bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
    t_model = r["model_flops_per_device"] / 197e12
    return (t_model / bound) if bound > 0 else 0.0


def markdown_table(recs):
    lines = [
        "| arch | shape | mesh | variant | GiB/dev | t_comp | t_mem | t_coll "
        "| dominant | useful/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        r = rec["roofline"]
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {rec.get('variant', 'baseline')} "
            f"| {rec['memory']['peak_per_device_gb']:.2f} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {fraction_of_roofline(rec):.3f} |")
    return "\n".join(lines)


def csv_rows(recs):
    rows = []
    for rec in recs:
        r = rec["roofline"]
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        rows.append(
            f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']},"
            f"{bound*1e6:.1f},"
            f"dom={r['dominant']} frac={fraction_of_roofline(rec):.3f} "
            f"mem={rec['memory']['peak_per_device_gb']:.2f}GiB")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DEFAULT_DIR)
    args = ap.parse_args()
    recs = load(args.dir)
    if not recs:
        print("no dry-run records found; run repro.launch.dryrun first")
        return
    print(markdown_table(recs))


if __name__ == "__main__":
    main()
