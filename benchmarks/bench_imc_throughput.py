"""IMC fabric projection + kernel-path throughput (paper §III-F made
quantitative, plus the TPU-side exact path and the sim-path engine race).

Projects transformer-layer GEMMs onto a sea of 8x8 macros using the
paper-calibrated energy/latency model, then times every fabric configuration
through ONE entry point — :func:`repro.core.fabric.fabric_matmul` with a
:class:`FabricSpec` — so each CSV row is labeled by the spec that produced it
(``exact/jnp``, ``exact/pallas``, ``sim/jnp``, ``sim/pallas``,
``sim/jnp+noise``) and the perf trajectory distinguishes backends.  The seed
per-plane-pair loop engine stays as the ``sim_loop`` baseline row.  Every
function takes ``smoke=True`` for the reduced CI matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core.fabric import Fabric, FabricSpec, NoiseSpec, fabric_matmul


def fabric_projection(smoke: bool = False):
    rows = []
    spec = FabricSpec()
    cases = [
        ("mlp_768x3072", 512, 768, 3072),  # imc-paper-110m MLP
        ("attn_qkv_2048", 512, 2048, 2048),  # qwen2.5-3b projection
        ("expert_ffn_qwen3moe", 512, 2048, 768),  # one expert GEMM
    ]
    if smoke:
        cases = cases[:1]
    fab = Fabric(spec)
    for name, m, k, n in cases:
        for macros in (1, 4096, 65536):
            rep = fab.cost((m, k), (k, n), n_macros=macros)
            rows.append(row(
                f"imc_fabric/{name}/macros{macros}", rep.latency_s * 1e6,
                f"E={rep.energy_j*1e6:.1f}uJ evals={rep.evaluations:.3g} "
                f"TOPS/W={rep.tops_per_w:.2f}"))
        cold = fab.cost((m, k), (k, n), schedule="cold")
        rows.append(row(
            f"imc_fabric/{name}/cold", cold.latency_s * 1e6,
            f"paper-63ns-per-op schedule; E={cold.energy_j*1e6:.1f}uJ"))
    return rows


def exact_path_throughput(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    shapes = [(256, 512, 512), (512, 1024, 1024)]
    iters = 10
    if smoke:
        shapes, iters = [(128, 256, 256)], 3
    for m, k, n in shapes:
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        spec = FabricSpec(mode="exact", backend="jnp")
        f = jax.jit(lambda x, w, s=spec: fabric_matmul(x, w, s))
        us, _ = time_fn(f, x, w, iters=iters)
        flops = 2 * m * k * n
        rows.append(row(f"imc/{spec.label}_{m}x{k}x{n}", us,
                        f"{flops/(us*1e-6)/1e9:.1f}GFLOP/s-int8-equiv"))
        spec_k = FabricSpec(mode="exact", backend="pallas")
        fk = jax.jit(lambda x, w, s=spec_k: fabric_matmul(x, w, s))
        us_k, _ = time_fn(fk, x, w, iters=min(iters, 3))
        rows.append(row(f"imc/{spec_k.label}_{m}x{k}x{n}", us_k,
                        "interpret=True on CPU (oracle-mode; not perf)"))
    return rows


def sim_path_throughput(smoke: bool = False):
    """Engine race on the hardware-faithful sim path, one row per spec label.

    ``sim_loop``      — seed per-plane-pair engine: bits^2 einsum+decode
                        rounds (pre-spec baseline, kept for the trajectory).
    ``sim/jnp``       — plane-batched engine: ONE batched contraction + ONE
                        vectorized decode + weighted accumulate.
    ``sim/jnp+noise`` — same engine with PRNG-keyed device mismatch at the
                        paper-calibrated sigma (keys folded per plane pair).
    ``sim/pallas``    — the fully fused bitplane_mac kernel, interpret mode
                        on CPU (correctness oracle, not a perf number
                        off-TPU).
    ``sim/pallas+noise`` — the noisy fast path: the same ONE-kernel pyramid
                        with the NoiseSpec Monte-Carlo drawn by the in-kernel
                        PRNG.  On TPU this row must meet or beat
                        ``sim/jnp+noise``; on CPU both pallas rows are
                        interpreter correctness numbers, not perf.
    """
    from repro.core.bitserial import bitserial_matmul_looped
    from repro.core.quant import quantize, to_offset_binary

    rows = []
    rng = np.random.default_rng(1)
    bits = 8
    key = jax.random.key(0)
    shapes = [(64, 256, 128), (128, 512, 256)]
    iters = 5
    if smoke:
        shapes, iters = [(32, 128, 64)], 3
    for m, k, n in shapes:
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        ua = to_offset_binary(quantize(x, bits).q, bits)
        uw = to_offset_binary(quantize(w, bits, axis=0).q, bits)
        floop = jax.jit(lambda a, b: bitserial_matmul_looped(
            a, b, bits_a=bits, bits_w=bits, mode="sim"))
        us_loop, out_loop = time_fn(floop, ua, uw, iters=iters)
        rows.append(row(f"imc/sim_loop_{m}x{k}x{n}", us_loop,
                        f"{bits * bits} einsum+decode rounds (seed engine)"))

        spec = FabricSpec(mode="sim", backend="jnp")
        ffused = jax.jit(lambda x, w, s=spec: fabric_matmul(x, w, s))
        us_fused, out_fused = time_fn(ffused, x, w, iters=iters)
        rows.append(row(f"imc/{spec.label}_{m}x{k}x{n}", us_fused,
                        f"plane-batched engine; {us_loop/us_fused:.2f}x vs "
                        "loop"))

        spec_n = FabricSpec(mode="sim", backend="jnp",
                            noise=NoiseSpec.calibrated())
        fnoise = jax.jit(lambda x, w, key, s=spec_n: fabric_matmul(
            x, w, s, key=key))
        us_noise, _ = time_fn(fnoise, x, w, key, iters=iters)
        rows.append(row(f"imc/{spec_n.label}_{m}x{k}x{n}", us_noise,
                        f"keyed mismatch; {us_noise/us_fused:.2f}x vs "
                        "noise-free"))

        if (m, k, n) == shapes[0]:
            spec_p = FabricSpec(mode="sim", backend="pallas")
            fker = jax.jit(lambda x, w, s=spec_p: fabric_matmul(x, w, s))
            us_ker, out_ker = time_fn(fker, x, w, iters=2, warmup=1)
            np.testing.assert_array_equal(np.asarray(out_fused),
                                          np.asarray(out_ker))
            rows.append(row(f"imc/{spec_p.label}_{m}x{k}x{n}", us_ker,
                            "interpret=True on CPU (oracle-mode; not perf)"))

            spec_pn = FabricSpec(mode="sim", backend="pallas",
                                 noise=NoiseSpec.calibrated())
            fkn = jax.jit(lambda x, w, key, s=spec_pn: fabric_matmul(
                x, w, s, key=key))
            us_kn, _ = time_fn(fkn, x, w, key, iters=2, warmup=1)
            rows.append(row(
                f"imc/{spec_pn.label}_{m}x{k}x{n}", us_kn,
                "in-kernel PRNG noise; interpret=True on CPU "
                "(oracle-mode; not perf)"))
    return rows


ALL = [fabric_projection, exact_path_throughput, sim_path_throughput]
