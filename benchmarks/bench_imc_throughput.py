"""IMC fabric projection + kernel-path throughput (paper §III-F made
quantitative, plus the TPU-side exact path).

Projects transformer-layer GEMMs onto a sea of 8x8 macros using the
paper-calibrated energy/latency model, and times the exact digital-equivalent
path (imc_matmul / Pallas kernel in interpret mode) on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core.energy import fabric_matmul_cost
from repro.core.imc_matmul import imc_matmul


def fabric_projection():
    rows = []
    cases = [
        ("mlp_768x3072", 512, 768, 3072),  # imc-paper-110m MLP
        ("attn_qkv_2048", 512, 2048, 2048),  # qwen2.5-3b projection
        ("expert_ffn_qwen3moe", 512, 2048, 768),  # one expert GEMM
    ]
    for name, m, k, n in cases:
        for macros in (1, 4096, 65536):
            rep = fabric_matmul_cost(m, k, n, n_macros=macros)
            rows.append(row(
                f"imc_fabric/{name}/macros{macros}", rep.latency_s * 1e6,
                f"E={rep.energy_j*1e6:.1f}uJ evals={rep.evaluations:.3g} "
                f"TOPS/W={rep.tops_per_w:.2f}"))
        cold = fabric_matmul_cost(m, k, n, schedule="cold")
        rows.append(row(
            f"imc_fabric/{name}/cold", cold.latency_s * 1e6,
            f"paper-63ns-per-op schedule; E={cold.energy_j*1e6:.1f}uJ"))
    return rows


def exact_path_throughput():
    rows = []
    rng = np.random.default_rng(0)
    for m, k, n in [(256, 512, 512), (512, 1024, 1024)]:
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        f = jax.jit(lambda x, w: imc_matmul(x, w, bits=8, mode="exact"))
        us, _ = time_fn(f, x, w, iters=10)
        flops = 2 * m * k * n
        rows.append(row(f"imc_exact/xla_{m}x{k}x{n}", us,
                        f"{flops/(us*1e-6)/1e9:.1f}GFLOP/s-int8-equiv"))
        fk = jax.jit(lambda x, w: imc_matmul(x, w, bits=8, mode="exact",
                                             use_kernel=True))
        us_k, _ = time_fn(fk, x, w, iters=3)
        rows.append(row(f"imc_exact/pallas_interp_{m}x{k}x{n}", us_k,
                        "interpret=True (CPU oracle-mode, not perf)"))
    return rows


ALL = [fabric_projection, exact_path_throughput]
