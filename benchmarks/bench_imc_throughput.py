"""IMC fabric projection + kernel-path throughput (paper §III-F made
quantitative, plus the TPU-side exact path and the sim-path engine race).

Projects transformer-layer GEMMs onto a sea of 8x8 macros using the
paper-calibrated energy/latency model, times the exact digital-equivalent
path, and races the hardware-faithful sim engines: the seed per-plane-pair
LOOP (64 einsum+decode rounds) vs the plane-batched FUSED engine (one
contraction + one vectorized decode) vs the fused Pallas kernel (oracle
interpret mode on CPU).  Every function takes ``smoke=True`` for the reduced
CI matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core.energy import fabric_matmul_cost
from repro.core.imc_matmul import imc_matmul
from repro.core.quant import quantize, to_offset_binary


def fabric_projection(smoke: bool = False):
    rows = []
    cases = [
        ("mlp_768x3072", 512, 768, 3072),  # imc-paper-110m MLP
        ("attn_qkv_2048", 512, 2048, 2048),  # qwen2.5-3b projection
        ("expert_ffn_qwen3moe", 512, 2048, 768),  # one expert GEMM
    ]
    if smoke:
        cases = cases[:1]
    for name, m, k, n in cases:
        for macros in (1, 4096, 65536):
            rep = fabric_matmul_cost(m, k, n, n_macros=macros)
            rows.append(row(
                f"imc_fabric/{name}/macros{macros}", rep.latency_s * 1e6,
                f"E={rep.energy_j*1e6:.1f}uJ evals={rep.evaluations:.3g} "
                f"TOPS/W={rep.tops_per_w:.2f}"))
        cold = fabric_matmul_cost(m, k, n, schedule="cold")
        rows.append(row(
            f"imc_fabric/{name}/cold", cold.latency_s * 1e6,
            f"paper-63ns-per-op schedule; E={cold.energy_j*1e6:.1f}uJ"))
    return rows


def exact_path_throughput(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    shapes = [(256, 512, 512), (512, 1024, 1024)]
    iters = 10
    if smoke:
        shapes, iters = [(128, 256, 256)], 3
    for m, k, n in shapes:
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        f = jax.jit(lambda x, w: imc_matmul(x, w, bits=8, mode="exact"))
        us, _ = time_fn(f, x, w, iters=iters)
        flops = 2 * m * k * n
        rows.append(row(f"imc_exact/xla_{m}x{k}x{n}", us,
                        f"{flops/(us*1e-6)/1e9:.1f}GFLOP/s-int8-equiv"))
        fk = jax.jit(lambda x, w: imc_matmul(x, w, bits=8, mode="exact",
                                             use_kernel=True))
        us_k, _ = time_fn(fk, x, w, iters=min(iters, 3))
        rows.append(row(f"imc_exact/pallas_interp_{m}x{k}x{n}", us_k,
                        "interpret=True (CPU oracle-mode; not perf)"))
    return rows


def sim_path_throughput(smoke: bool = False):
    """Engine race on the hardware-faithful sim path: loop vs fused.

    ``sim_loop``  — seed per-plane-pair engine: bits^2 einsum+decode rounds.
    ``sim_fused`` — plane-batched engine: ONE batched contraction + ONE
                    vectorized decode + weighted accumulate (the default
                    ``imc_matmul(mode="sim")`` path).
    ``sim_pallas``— the fully fused bitplane_mac kernel, interpret mode on
                    CPU (correctness oracle, not a perf number off-TPU).
    """
    from repro.core.bitserial import (bitserial_matmul_looped,
                                      bitserial_matmul_unsigned)
    from repro.kernels.bitplane_mac.ops import bitplane_mac

    rows = []
    rng = np.random.default_rng(1)
    bits = 8
    shapes = [(64, 256, 128), (128, 512, 256)]
    iters = 5
    if smoke:
        shapes, iters = [(32, 128, 64)], 3
    for m, k, n in shapes:
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        ua = to_offset_binary(quantize(x, bits).q, bits)
        uw = to_offset_binary(quantize(w, bits, axis=0).q, bits)
        floop = jax.jit(lambda a, b: bitserial_matmul_looped(
            a, b, bits_a=bits, bits_w=bits, mode="sim"))
        us_loop, out_loop = time_fn(floop, ua, uw, iters=iters)
        rows.append(row(f"imc_sim/loop_{m}x{k}x{n}", us_loop,
                        f"{bits * bits} einsum+decode rounds (seed engine)"))
        ffused = jax.jit(lambda a, b: bitserial_matmul_unsigned(
            a, b, bits_a=bits, bits_w=bits, mode="sim"))
        us_fused, out_fused = time_fn(ffused, ua, uw, iters=iters)
        assert np.array_equal(np.asarray(out_loop), np.asarray(out_fused))
        rows.append(row(f"imc_sim/fused_{m}x{k}x{n}", us_fused,
                        f"plane-batched engine; {us_loop/us_fused:.2f}x vs "
                        "loop"))
        if (m, k, n) == shapes[0]:
            fker = jax.jit(lambda a, b: bitplane_mac(
                a, b, bits_a=bits, bits_w=bits))
            us_ker, out_ker = time_fn(fker, ua, uw, iters=2, warmup=1)
            assert np.array_equal(np.asarray(out_loop), np.asarray(out_ker))
            rows.append(row(f"imc_sim/pallas_interp_{m}x{k}x{n}", us_ker,
                            "interpret=True (CPU oracle-mode; not perf)"))
    return rows


ALL = [fabric_projection, exact_path_throughput, sim_path_throughput]
