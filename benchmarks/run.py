"""Benchmark runner: one function per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV (optionally teeing to ``--out`` for CI
artifact upload).  ``--smoke`` runs the reduced matrix — small shapes, fewer
iterations — so a CPU CI runner finishes in a couple of minutes while still
seeding the perf trajectory.  Roofline rows appear when dry-run records exist
under experiments/dryrun/.
"""
from __future__ import annotations

import argparse
import inspect


def _rows_from(fn, smoke: bool):
    if "smoke" in inspect.signature(fn).parameters:
        return fn(smoke=smoke)
    return fn()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced bench matrix (CI smoke; seeds perf CSV)")
    ap.add_argument("--out", default=None,
                    help="also write the CSV to this path")
    args = ap.parse_args(argv)

    from benchmarks import bench_imc_throughput, bench_paper_tables, roofline

    lines = ["name,us_per_call,derived"]
    print(lines[0])
    for fn in (*bench_paper_tables.ALL, *bench_imc_throughput.ALL):
        for r in _rows_from(fn, args.smoke):
            lines.append(r)
            print(r, flush=True)
    for r in roofline.csv_rows(roofline.load()):
        lines.append(r)
        print(r, flush=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
