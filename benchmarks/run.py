"""Benchmark runner: one function per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV (optionally teeing to ``--out`` for CI
artifact upload).  ``--smoke`` runs the reduced matrix — small shapes, fewer
iterations — so a CPU CI runner finishes in a couple of minutes while still
seeding the perf trajectory.  Roofline rows appear when dry-run records exist
under experiments/dryrun/.

``--json [PATH]`` additionally runs the Engine-backed continuous-batching
serve bench per FabricSpec (float / exact / sim / noisy-sim) and writes
per-spec rows — tokens/s and steady-state decode-step ms — to ``PATH``
(default ``BENCH_imc.json``), the machine-readable start of the serving perf
trajectory.
"""
from __future__ import annotations

import argparse
import inspect
import json


def _rows_from(fn, smoke: bool):
    if "smoke" in inspect.signature(fn).parameters:
        return fn(smoke=smoke)
    return fn()


def serve_spec_rows(smoke: bool = True):
    """Continuous-batching serve throughput per FabricSpec (reduced arch)."""
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_config, reduce_config
    from repro.core.fabric import FabricSpec, NoiseSpec
    from repro.launch.engine import Engine
    from repro.launch.serve import BatchedServer, Request
    from repro.models.model import init_params
    from repro.runtime.straggler import StragglerMonitor

    cfg0 = reduce_config(get_config("qwen2.5-3b"))
    specs = [
        ("float", None),
        (None, FabricSpec(mode="exact", backend="jnp")),
        (None, FabricSpec(bits_a=4, bits_w=4, mode="sim", backend="jnp")),
        (None, FabricSpec(bits_a=4, bits_w=4, mode="sim", backend="jnp",
                          noise=NoiseSpec(mismatch_sigma=0.05))),
    ]
    n_req, max_new = (4, 6) if smoke else (8, 16)
    params = init_params(jax.random.key(0), cfg0)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg0.vocab_size, size=16).astype(np.int32)
               for _ in range(n_req)]
    rows = []
    for label, spec in specs:
        cfg = dataclasses.replace(cfg0, fabric=spec, imc_mode="off")
        engine = Engine(monitor=StragglerMonitor())
        with engine.activate():
            server = BatchedServer(cfg, params, slots=4, prompt_len=16,
                                   max_new=max_new, engine=engine)
            reqs = [Request(i, p, max_new) for i, p in enumerate(prompts)]
            _, tps = server.run(reqs)
        host = engine.monitor.hosts.get(0)
        rows.append({
            "spec": label or spec.label,
            "arch": cfg0.name,
            "tokens_per_s": round(tps, 2),
            "step_ms": round(host.ewma_time * 1e3, 3) if host else None,
            "compiled_steps": engine.stats.compiles,
            "traces": engine.stats.traces,
        })
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced bench matrix (CI smoke; seeds perf CSV)")
    ap.add_argument("--out", default=None,
                    help="also write the CSV to this path")
    ap.add_argument("--json", nargs="?", const="BENCH_imc.json", default=None,
                    metavar="PATH",
                    help="run the per-spec serve bench and write JSON rows "
                         "(tokens/s, step ms) to PATH")
    args = ap.parse_args(argv)

    from benchmarks import bench_imc_throughput, bench_paper_tables, roofline

    lines = ["name,us_per_call,derived"]
    print(lines[0])
    for fn in (*bench_paper_tables.ALL, *bench_imc_throughput.ALL):
        for r in _rows_from(fn, args.smoke):
            lines.append(r)
            print(r, flush=True)
    for r in roofline.csv_rows(roofline.load()):
        lines.append(r)
        print(r, flush=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(lines) + "\n")
    if args.json:
        rows = serve_spec_rows(smoke=args.smoke)
        rec = {"benchmark": "continuous_batching_serve", "smoke": args.smoke,
               "rows": rows}
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=1)
        for r in rows:
            print(f"serve/{r['spec']},{r['step_ms']},"
                  f"{r['tokens_per_s']} tok/s", flush=True)


if __name__ == "__main__":
    main()
