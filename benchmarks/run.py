"""Benchmark runner: one function per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV.  Roofline rows appear when dry-run
records exist under experiments/dryrun/.
"""
from __future__ import annotations


def main() -> None:
    from benchmarks import bench_imc_throughput, bench_paper_tables, roofline

    print("name,us_per_call,derived")
    for fn in bench_paper_tables.ALL:
        for r in fn():
            print(r, flush=True)
    for fn in bench_imc_throughput.ALL:
        for r in fn():
            print(r, flush=True)
    for r in roofline.csv_rows(roofline.load()):
        print(r, flush=True)


if __name__ == "__main__":
    main()
