"""Benchmark runner: one function per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV (optionally teeing to ``--out`` for CI
artifact upload).  ``--smoke`` runs the reduced matrix — small shapes, fewer
iterations — so a CPU CI runner finishes in a couple of minutes while still
seeding the perf trajectory.  Roofline rows appear when dry-run records exist
under experiments/dryrun/.

``--json [PATH]`` additionally runs the Engine-backed continuous-batching
serve bench per (FabricSpec x KV geometry) — float / exact / sim / noisy-sim
(both the keyed jnp engine and the in-kernel-PRNG ``sim/pallas+noise`` fast
path), each under the legacy fixed ring AND the paged block pool, plus one
ragged-admission paged row and paged-kernel (``attn_impl='pallas'``) siblings
of the float paged rows — and writes rows (tokens/s, steady-state
decode-step ms, attn_impl tag) to ``PATH`` (default ``BENCH_imc.json``).
``--autotune`` first resolves the standard kernel-geometry cells through
``repro.kernels.autotune`` (trial-free on the committed cache).

``--compare OLD NEW`` diffs two such JSON files (tokens/s, step ms, % delta)
as a markdown table keyed by (spec, kv, mix, attn_impl) — jnp-path numbers
are never diffed against kernel-path numbers — and CI posts it against the
previous main artifact.

The CSV path includes ``paged_decode_attn/*`` rows (bench_decode_attn): the
decode-attention op swept over context length, one row per attn_impl.
"""
from __future__ import annotations

import argparse
import inspect
import json


def _rows_from(fn, smoke: bool):
    if "smoke" in inspect.signature(fn).parameters:
        return fn(smoke=smoke)
    return fn()


def _serve_once(cfg, params, lengths, max_new, kv, attn_impl=None):
    """One Server run: warmup wave (compiles) + timed wave; returns a row.

    Each run gets its OWN telemetry Registry (no cross-row contamination),
    and the row carries the serving SLO trio (TTFT/TPOT/occupancy peak) plus
    the full telemetry snapshot for BENCH_imc.json.  Every row is tagged
    with the decode-attention engine that produced it (``attn_impl``), and
    paged-kernel rows run off-TPU carry ``interpret: true`` — interpreter
    throughput is an oracle-mode number, not perf.
    """
    import jax
    import numpy as np

    from repro.launch.engine import Engine
    from repro.launch.server import Request, Server
    from repro.runtime.straggler import StragglerMonitor
    from repro.telemetry import Registry, clock, serving_slos, snapshot

    buckets = sorted({-(-n // 16) * 16 for n in lengths})
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lengths]
    registry = Registry()
    engine = Engine(monitor=StragglerMonitor(), registry=registry)
    with engine.activate():
        server = Server(cfg, params, engine=engine, slots=4, kv=kv,
                        block_size=8, buckets=buckets, attn_impl=attn_impl,
                        max_seq_len=max(buckets) + max_new)
        for p in prompts:  # warmup wave: traces + compiles land here
            server.submit(Request(p, max_new_tokens=max_new))
        server.drain()
        warm = engine.stats.traces
        registry.reset()  # SLOs cover the timed (steady-state) waves only
        timed = []
        d0, t0 = server.decode_s, clock()
        for _ in range(4):  # several timed waves: averages out host jitter
            wave = [server.submit(Request(p, max_new_tokens=max_new))
                    for p in prompts]
            server.drain()
            timed += wave
        dt = clock() - t0
        decode_dt = server.decode_s - d0
    assert engine.stats.traces == warm, "steady-state recompile in bench"
    # tokens/s is LOCKSTEP-DECODE throughput: each handle's first token comes
    # from prefill logits, the rest from decode ticks timed device-side via
    # Server.decode_s.
    tokens = sum(len(h.tokens) - 1 for h in timed)
    host = engine.monitor.hosts.get(0)
    row = {
        "tokens_per_s": round(tokens / decode_dt, 2),
        "e2e_tokens_per_s": round(sum(len(h.tokens) for h in timed) / dt, 2),
        "step_ms": round(host.ewma_time * 1e3, 3) if host else None,
        "compiled_steps": engine.stats.compiles,
        "traces": engine.stats.traces,
        **serving_slos(registry, attn_impl=server.attn_impl, n_hosts=1),
        "telemetry": snapshot(registry),
    }
    if server.attn_impl == "pallas" and jax.default_backend() != "tpu":
        row["interpret"] = True  # CPU interpreter row: exempt from perf bars
    return row


def _serve_fleet_once(cfg, params, lengths, max_new, kv, n_hosts,
                      attn_impl=None):
    """One FleetServer run over an N-host virtual fleet; returns a row.

    Same warmup + timed-waves protocol as :func:`_serve_once`, with SLOs read
    off the MERGED per-host registry view (exact fleet percentiles) and the
    row tagged ``n_hosts=N`` so ``--compare`` never diffs it against a
    single-host sibling.
    """
    import numpy as np

    from repro.fleet import FleetEngine, FleetServer, LocalCoordinator
    from repro.launch.server import Request
    from repro.telemetry import clock, serving_slos, snapshot

    buckets = sorted({-(-n // 16) * 16 for n in lengths})
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lengths]
    fleet = FleetEngine(LocalCoordinator(n_hosts))
    server = FleetServer(cfg, params, fleet, slots=4, kv=kv, block_size=8,
                         buckets=buckets, attn_impl=attn_impl,
                         max_seq_len=max(buckets) + max_new)
    for p in prompts:  # warmup wave: traces + compiles land here
        server.submit(Request(p, max_new_tokens=max_new))
    server.drain()
    warm = fleet.total_traces()
    for h in fleet.active_hosts():  # SLOs cover steady-state waves only
        fleet.engine(h).registry.reset()
    timed = []
    d0, t0 = server.total_decode_s(), clock()
    for _ in range(4):
        wave = [server.submit(Request(p, max_new_tokens=max_new))
                for p in prompts]
        server.drain()
        timed += wave
    dt = clock() - t0
    decode_dt = server.total_decode_s() - d0
    assert fleet.total_traces() == warm, "steady-state recompile in bench"
    tokens = sum(len(h.tokens) - 1 for h in timed)
    merged = fleet.merged_registry()
    ewmas = [fleet.monitor.hosts[h].ewma_time
             for h in fleet.active_hosts() if h in fleet.monitor.hosts]
    row = {
        "tokens_per_s": round(tokens / decode_dt, 2),
        "e2e_tokens_per_s": round(sum(len(h.tokens) for h in timed) / dt, 2),
        "step_ms": round(1e3 * sum(ewmas) / len(ewmas), 3) if ewmas else None,
        "compiled_steps": sum(fleet.engine(h).stats.compiles
                              for h in fleet.active_hosts()),
        "traces": fleet.total_traces(),
        **serving_slos(merged, attn_impl=server.attn_impl, n_hosts=n_hosts),
        "telemetry": snapshot(merged),
    }
    return row


def serve_spec_rows(smoke: bool = True):
    """Serve throughput per (FabricSpec x kv geometry), reduced arch.

    Every spec runs under both ``kv='ring'`` (the legacy fixed-ring oracle)
    and ``kv='paged'`` at one uniform prompt length — the paged row must not
    regress tokens/s vs its ring sibling.  One extra ragged-mix paged row
    (prompt lengths 7/16/33) covers the admission path ring cannot serve.

    The float spec additionally runs its paged rows (uniform + ragged) with
    ``attn_impl='pallas'`` — the fused flash-decode kernel vs the jnp gather
    path on identical traffic.  On TPU the kernel row must meet or beat its
    jnp sibling at long contexts; on CPU it is an interpreter-correctness
    row (tagged ``interpret: true``).
    """
    import dataclasses

    import jax

    from repro.configs import get_config, reduce_config
    from repro.core.fabric import FabricSpec, NoiseSpec
    from repro.models.model import init_params

    cfg0 = reduce_config(get_config("qwen2.5-3b"))
    specs = [
        ("float", None),
        (None, FabricSpec(mode="exact", backend="jnp")),
        (None, FabricSpec(bits_a=4, bits_w=4, mode="sim", backend="jnp")),
        (None, FabricSpec(bits_a=4, bits_w=4, mode="sim", backend="jnp",
                          noise=NoiseSpec(mismatch_sigma=0.05))),
        # noisy Pallas fast path: the same NoiseSpec drawn by the in-kernel
        # PRNG inside the fused bitplane_mac kernel (one pallas_call).  Off
        # TPU this serves through the interpreter — a correctness row, not
        # perf — and is tagged ``interpret: true`` below.
        (None, FabricSpec(bits_a=4, bits_w=4, mode="sim", backend="pallas",
                          noise=NoiseSpec(mismatch_sigma=0.05))),
    ]
    n_req, max_new = (4, 6) if smoke else (8, 16)
    uniform = [16] * n_req
    ragged = [(7, 16, 33)[i % 3] for i in range(n_req)]
    params = init_params(jax.random.key(0), cfg0)
    matrix = [(label, spec, kv, mix, lens, None)
              for label, spec in specs
              for kv, mix, lens in (("ring", "uniform", uniform),
                                    ("paged", "uniform", uniform))]
    matrix.append(("float", None, "paged", "ragged", ragged, None))
    # paged-kernel siblings of the float paged rows: same traffic, fused
    # flash-decode attention instead of the dense gather
    matrix.append(("float", None, "paged", "uniform", uniform, "pallas"))
    matrix.append(("float", None, "paged", "ragged", ragged, "pallas"))
    rows = []
    for label, spec, kv, mix, lens, attn_impl in matrix:
        cfg = dataclasses.replace(cfg0, fabric=spec, imc_mode="off")
        row = _serve_once(cfg, params, lens, max_new, kv,
                         attn_impl=attn_impl)
        if (spec is not None and spec.backend == "pallas"
                and jax.default_backend() != "tpu"):
            row["interpret"] = True  # fabric kernel ran in the interpreter
        rows.append({"spec": label or spec.label, "kv": kv, "mix": mix,
                     "arch": cfg0.name, **row})
    # virtual-fleet sibling of the float paged uniform row: same traffic
    # split over 2 hosts, SLOs off the merged registry (needs >= 2 devices;
    # CI forces them with --xla_force_host_platform_device_count)
    if len(jax.devices()) >= 2:
        cfg = dataclasses.replace(cfg0, fabric=None, imc_mode="off")
        row = _serve_fleet_once(cfg, params, uniform, max_new, "paged", 2)
        rows.append({"spec": "float", "kv": "paged", "mix": "uniform",
                     "arch": cfg0.name, **row})
    return rows


def compare(old_path: str, new_path: str) -> None:
    """Diff two BENCH_imc.json runs row-by-row (markdown table to stdout).

    Rows are keyed by (spec, noise_engine, kv, mix, attn_impl, n_hosts) — a
    jnp-path row is never diffed against a kernel-path row, a noisy row
    drawn by the in-kernel PRNG (``sim/pallas+noise``) is never diffed
    against one drawn by the keyed jnp engine (``sim/jnp+noise``), and a
    single-host row is never diffed against a fleet row.  Files predating
    the ``attn_impl`` / ``n_hosts`` tags default to what they actually ran:
    ``ring`` geometry or the jnp gather path, and one host.
    """
    def impl_of(r):
        kv = r.get("kv", "ring")
        return r.get("attn_impl", "ring" if kv == "ring" else "jnp")

    def noise_of(r):
        # the noise ENGINE is the backend half of a noisy spec label
        # ("sim/jnp+noise" -> "jnp", "sim/pallas+noise" -> "pallas");
        # noise-free rows key as "-" so they only ever diff against each
        # other.
        label = r.get("spec", "")
        if "+noise" not in label:
            return "-"
        return label.split("/", 1)[-1].split("+", 1)[0]

    def load(p):
        with open(p) as f:
            rec = json.load(f)
        return {(r["spec"], noise_of(r), r.get("kv", "ring"),
                 r.get("mix", "uniform"), impl_of(r),
                 r.get("n_hosts", 1) or 1): r
                for r in rec["rows"]}

    def pct(old, new):
        if not old or old in (None, 0) or new is None:
            return "n/a"
        return f"{100.0 * (new - old) / old:+.1f}%"

    old, new = load(old_path), load(new_path)
    print("| spec | noise | kv | mix | attn | hosts | tok/s old | tok/s new "
          "| Δ | step ms old | step ms new | Δ | ttft ms old | ttft ms new "
          "| Δ | tpot ms old | tpot ms new | Δ |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|"
          "---|---|---|")
    for key in sorted(set(old) | set(new)):
        o, n = old.get(key, {}), new.get(key, {})
        attn = key[4] + (" (interpret)" if (o.get("interpret")
                                            or n.get("interpret")) else "")
        cells = [key[0], key[1], key[2], key[3], attn, key[5]]
        for field in ("tokens_per_s", "step_ms", "ttft_ms", "tpot_ms"):
            ov, nv = o.get(field), n.get(field)
            cells += [ov if ov is not None else "—",
                      nv if nv is not None else "—", pct(ov, nv)]
        print("| " + " | ".join(str(c) for c in cells) + " |")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced bench matrix (CI smoke; seeds perf CSV)")
    ap.add_argument("--out", default=None,
                    help="also write the CSV to this path")
    ap.add_argument("--json", nargs="?", const="BENCH_imc.json", default=None,
                    metavar="PATH",
                    help="run the per-spec serve bench and write JSON rows "
                         "(tokens/s, step ms) to PATH")
    ap.add_argument("--compare", nargs=2, metavar=("OLD", "NEW"), default=None,
                    help="diff two BENCH_imc.json runs (tokens/s, step ms, "
                         "%% delta) as a markdown table; runs nothing else")
    ap.add_argument("--autotune", action="store_true",
                    help="(re-)tune the standard kernel cells before "
                         "benching; cached cells resolve trial-free, so on "
                         "a warm cache this is a no-op assertion")
    args = ap.parse_args(argv)

    if args.compare:
        compare(*args.compare)
        return

    if args.autotune:
        from repro.kernels import autotune
        for kernel, bucket, geom, backend in autotune.tune_standard(
                smoke=args.smoke):
            print(f"autotune/{kernel}/{bucket}/{backend},"
                  f"{' '.join(f'{k}={v}' for k, v in sorted(geom.items()))}",
                  flush=True)

    from benchmarks import (bench_decode_attn, bench_imc_throughput,
                            bench_paper_tables, roofline)

    lines = ["name,us_per_call,derived"]
    print(lines[0])
    for fn in (*bench_paper_tables.ALL, *bench_imc_throughput.ALL,
               *bench_decode_attn.ALL):
        for r in _rows_from(fn, args.smoke):
            lines.append(r)
            print(r, flush=True)
    for r in roofline.csv_rows(roofline.load()):
        lines.append(r)
        print(r, flush=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(lines) + "\n")
    if args.json:
        rows = serve_spec_rows(smoke=args.smoke)
        rec = {"benchmark": "continuous_batching_serve", "smoke": args.smoke,
               "rows": rows}
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=1)
        for r in rows:
            print(f"serve/{r['spec']}/{r['kv']}/{r['mix']}/{r['attn_impl']},"
                  f"{r['step_ms']},{r['tokens_per_s']} tok/s", flush=True)


if __name__ == "__main__":
    main()
