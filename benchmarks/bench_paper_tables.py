"""Benchmarks reproducing every table/figure of the paper.

Each function returns CSV rows ``name,us_per_call,derived`` where ``derived``
carries the reproduced quantity next to the paper's value.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import constants as C
from repro.core.array import ArraySpec, empty_state, logic2, mac, write
from repro.core.decoder import decode_voltage
from repro.core.energy import Timing, logic_energy_fj, mac_energy_fj
from repro.core.montecarlo import mc_stats
from repro.core.rbl import rbl_voltage


def table1_mac_voltage():
    """Table I: RBL voltage + decoded count for every MAC count."""
    ks = jnp.arange(9)
    f = jax.jit(lambda k: (rbl_voltage(k), decode_voltage(rbl_voltage(k))))
    us, (v, dec) = time_fn(f, ks)
    rows = []
    for k in range(9):
        ref = C.V_RBL_TABLE[k]
        rows.append(row(f"table1/mac{k}", us / 9,
                        f"V_RBL={float(v[k]):.3f}V (paper {ref:.3f}V) "
                        f"decoded={int(dec[k])}"))
    vp = rbl_voltage(ks, mode="physics")
    err = float(jnp.max(jnp.abs(vp - jnp.asarray(C.V_RBL_TABLE, jnp.float32))))
    rows.append(row("table1/physics_fit_max_err", us, f"{err*1000:.1f}mV"))
    return rows


def table2_logic():
    """Table II: AND/NOR/XOR interpretation for all 2-bit input patterns."""
    rows = []
    spec = ArraySpec()
    for a, b in [(0, 0), (0, 1), (1, 0), (1, 1)]:
        state = write(empty_state(spec),
                      np.tile([[a], [b]], (4, 8))[:8].astype(np.uint8))
        f = jax.jit(lambda s: logic2(s, 0, 1, spec)[0])
        us, out = time_fn(f, state)
        rows.append(row(
            f"table2/data_{a}{b}", us,
            f"AND={int(out['AND'][0])} NOR={int(out['NOR'][0])} "
            f"XOR={int(out['XOR'][0])} (expect {a & b},{1 - (a | b)},{a ^ b})"))
    return rows


def table3_mac_energy():
    """Table III: RBL energy per MAC count."""
    f = jax.jit(lambda k: mac_energy_fj(k))
    us, e = time_fn(f, jnp.arange(9))
    return [row(f"table3/mac{k}", us / 9,
                f"E={float(e[k]):.1f}fJ (paper {C.E_MAC_TABLE_FJ[k]}fJ)")
            for k in range(9)]


def table4_logic_energy():
    """Table IV: 1-bit logic op energies."""
    rows = []
    for op, ref in [("AND", 212.7), ("NOR", 5.369), ("XOR", 119.3),
                    ("SUM", 119.3), ("CARRY", 212.7)]:
        e = logic_energy_fj(op)
        rows.append(row(f"table4/{op}", 0.0,
                        f"E={e}fJ (paper {ref}fJ)"))
    return rows


def table5_comparison():
    """Table V: this work's headline numbers (vs prior-work table)."""
    t = Timing()
    return [
        row("table5/frequency", 0.0,
            f"{t.f_clk_hz/1e6:.2f}MHz (paper 142.85MHz)"),
        row("table5/energy_per_bit", 0.0,
            f"{C.ENERGY_PER_BIT_FJ:.2f}fJ/bit (paper 56.56)"),
        row("table5/operands", 0.0, "N (multi-operand MAC, paper: N)"),
        row("table5/ops", 0.0,
            "MAC+AND/NAND/OR/NOR/XOR/XNOR/ADD from one evaluation"),
    ]


def fig5_timing():
    """Fig 5: full-operation waveform timing on the behavioral array."""
    spec = ArraySpec()
    ones = np.ones((8, 8), np.uint8)

    def full_op(bits):
        state = write(empty_state(spec), bits)  # 8 write cycles
        return mac(state, jnp.ones(8, jnp.uint8), spec)  # precharge+eval

    f = jax.jit(full_op)
    us, res = time_fn(f, jnp.asarray(ones))
    t = Timing()
    return [
        row("fig5/full_op", us,
            f"model={t.t_op_s*1e9:.0f}ns (paper 63ns) "
            f"eval={t.t_eval_s*1e9:.1f}ns (paper 0.7ns) "
            f"decoded_mac={int(res.counts[0])} code="
            f"{''.join(str(int(b)) for b in res.codes[0])}"),
        row("fig5/throughput", us,
            f"{t.throughput_ops/1e6:.2f}Mops/s (paper 15.8)"),
    ]


def fig6_montecarlo():
    """Fig 6: Monte-Carlo energy distribution at MAC count 8."""
    f = jax.jit(lambda k: mc_stats(k, 8, 200))
    us, (m, s) = time_fn(f, jax.random.key(0))
    m2, s2 = mc_stats(jax.random.key(1), 8, 200_000)
    return [
        row("fig6/mc200", us,
            f"mean={float(m):.1f}fJ std={float(s):.2f}fJ "
            f"(paper 437/48.72, n=200)"),
        row("fig6/mc200k", us,
            f"mean={float(m2):.1f}fJ std={float(s2):.2f}fJ (asymptotic)"),
    ]


ALL = [table1_mac_voltage, table2_logic, table3_mac_energy,
       table4_logic_energy, table5_comparison, fig5_timing, fig6_montecarlo]
